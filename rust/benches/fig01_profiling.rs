//! Bench: regenerate the §3 profiling study (Figs. 1–4 + Table 1) and
//! time the profiling pipeline itself.
//!
//! Run: `cargo bench --bench fig01_profiling`

use sentinel_hm::dnn::zoo::Model;
use sentinel_hm::dnn::StepTrace;
use sentinel_hm::figures;
use sentinel_hm::profiler::profile;
use sentinel_hm::util::bench::time_it;

fn main() {
    let model = Model::ResNetV1 { depth: 32 };

    // Time the measurement pipeline (graph build + trace + profile).
    let t = time_it(5, || {
        let g = model.build(0x5E17);
        let tr = StepTrace::from_graph(&g);
        profile(&g, &tr)
    });
    t.report("profile pipeline (ResNet_v1-32)");

    println!("\n=== Fig 1 — object lifetime distribution ===");
    let (table, short_frac) = figures::fig1_lifetime(model);
    table.print();
    println!(
        "paper: 92% of objects live ≤ 1 layer | measured: {:.1}%",
        short_frac * 100.0
    );

    println!("\n=== Fig 2 — accesses per data object (all) ===");
    figures::fig2_fig3_access(model, false).print();
    println!("paper: 52.3% of objects see < 10 accesses");

    println!("\n=== Fig 3 — accesses per data object (< 4KB) ===");
    figures::fig2_fig3_access(model, true).print();

    println!("\n=== Fig 4 — page-level false sharing ===");
    let (table, fs) = figures::fig4_false_sharing(model);
    table.print();
    println!("paper: page-level counts mislead (Observation 3); mixed pages here: {fs}");

    println!("\n=== Table 1 — profiling memory inflation ===");
    figures::table1_memory(model).print();
    println!("paper: 1.97 GB vs 1.57 GB total; 152 MB vs 0.45 MB for <4KB objects");
}
