//! Bench: Fig. 8 — occurrences of the three migration cases per training
//! step as the migration interval varies (ResNet_v1-32, 1 GB fast).
//!
//! Expected shape: Case 3 (out of time) rises as MI shrinks; Case 2
//! (out of space) rises as MI grows; the sweet spot sits where both
//! vanish.
//!
//! Run: `cargo bench --bench fig08_cases`

use sentinel_hm::figures::fig8_cases;
use sentinel_hm::util::bench::time_it;
use sentinel_hm::util::table::Table;

fn main() {
    let fast = 1u64 << 30;
    let mis: Vec<u32> = (1..=16).collect();

    let t = time_it(3, || fig8_cases(fast, &mis));
    t.report("fig8 case counts (16 MIs x 10 steps)");

    let rows = fig8_cases(fast, &mis);
    println!("\n=== Fig 8 — migration cases per training step ===");
    let mut table = Table::new(vec!["MI", "Case 1 (done)", "Case 2 (space)", "Case 3 (time)"]);
    for (mi, c1, c2, c3) in &rows {
        table.row(vec![mi.to_string(), c1.to_string(), c2.to_string(), c3.to_string()]);
    }
    table.print();

    let small_mi_case3 = rows.iter().take(4).map(|r| r.3).sum::<u64>();
    let large_mi_case3 = rows.iter().rev().take(4).map(|r| r.3).sum::<u64>();
    println!(
        "\npaper: MI 11→5 raises Case 3 from 0 to 13; MI 5→11 raises Case 2 0→4\n\
         measured: case3 at small MIs = {small_mi_case3}, at large MIs = {large_mi_case3}"
    );
}
