//! Bench: Fig. 7 — training throughput vs migration interval
//! (ResNet_v1-32, 1 GB fast memory).
//!
//! Expected shape: an interior sweet spot — small MIs lose to exposed
//! migration (Case 3), large MIs to fast-memory pressure (Case 2).
//!
//! Run: `cargo bench --bench fig07_mi_sweep`

use sentinel_hm::figures::fig7_mi_sweep;
use sentinel_hm::util::bench::time_it;

fn main() {
    let fast = 1u64 << 30;
    let mis: Vec<u32> = (1..=16).collect();

    let t = time_it(3, || fig7_mi_sweep(fast, &mis));
    t.report("fig7 sweep (16 MIs x 10 steps)");

    let (rows, sp) = fig7_mi_sweep(fast, &mis);
    println!("\n=== Fig 7 — throughput vs migration interval (1 GB fast) ===");
    let max = rows.iter().map(|r| r.1).fold(0.0, f64::max);
    for (mi, thr) in &rows {
        let bar = "#".repeat((thr / max * 48.0) as usize);
        println!(
            "MI={mi:2}  {thr:6.3} steps/s  {bar}{}",
            if *mi == sp { "  <- SP" } else { "" }
        );
    }
    println!(
        "\npaper: ~21% variance over MI∈[5,11], sweet spot at 8 | \
         measured SP={sp}, variance {:.1}%",
        (max - rows.iter().map(|r| r.1).fold(f64::INFINITY, f64::min)) / max * 100.0
    );
}
