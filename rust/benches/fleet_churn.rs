//! Bench: fleet-scale churn — what an open-loop serving simulation
//! costs end to end (arrival generation, admission, join/leave
//! re-arbitration, sealed-schedule replay, solo baselines), and how the
//! per-round machine fan-out scales with worker threads.
//!
//! Run: `cargo bench --bench fleet_churn`
//!
//! The headline scenario is a 10,000-tenant fleet (override with
//! `FLEET_BENCH_TENANTS`); the acceptance bar is "simulates in
//! seconds", reported as `fleet_tenants_per_s` in the JSON summary
//! line.

use sentinel_hm::api::{json, Admission, FleetSpec};
use sentinel_hm::util::bench::time_it;

fn fleet(tenants: usize, machines: usize, threads: usize) -> FleetSpec {
    FleetSpec::new()
        .tenants(tenants)
        .rate_per_s(2.0)
        .machines(machines)
        .machine_fast_bytes(2 << 30)
        .admission(Admission::Queue)
        .threads(threads)
        .seed(7)
}

fn main() {
    let big: usize = std::env::var("FLEET_BENCH_TENANTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10_000);

    // Warm the workload, trace, and solo-baseline caches so the numbers
    // measure the fleet driver, not graph construction.
    fleet(16, 2, 1).run().expect("warm-up fleet");

    let mut summary = json::Obj::new().field_str("bench", "fleet_churn");
    for (key, tenants, machines, threads) in [
        ("fleet_200t_2m_serial_ns", 200usize, 2usize, 1usize),
        ("fleet_1k_8m_par_ns", 1_000, 8, 0),
    ] {
        let spec = fleet(tenants, machines, threads);
        let t = time_it(3, || spec.run().expect("fleet run"));
        t.report(&format!("fleet {tenants} jobs / {machines} machines (threads={threads})"));
        summary = summary.field_f64(key, t.median_ns as f64);
    }

    // Headline: the 10k-tenant churn scenario, once (three timed reps
    // would dominate the suite).
    let spec = fleet(big, 16, 0);
    let t = time_it(1, || spec.run().expect("10k fleet run"));
    t.report(&format!("fleet {big} jobs / 16 machines (threads=auto)"));
    let tenants_per_s = big as f64 / (t.median_ns as f64 / 1e9);
    summary = summary
        .field_f64("fleet_10k_ns", t.median_ns as f64)
        .field_f64("fleet_tenants_per_s", tenants_per_s);

    // Shape sanity: every offered job is accounted for, and the churn
    // counters moved.
    let out = fleet(200, 2, 0).run().unwrap();
    assert_eq!(out.completed + out.rejected, out.jobs_offered);
    assert!(out.makespan_ns > 0.0);
    assert!(out.fleet_events > 0);
    assert!(!out.samples.is_empty());

    println!("\n{}", summary.end());
}
