//! Bench: Fig. 13 — peak memory consumption vs the minimum fast-memory
//! size with which Sentinel matches the fast-memory-only system, across
//! ResNet_v1 depth variants (20/32/44/56/110).
//!
//! Expected shape (paper): peak memory grows quickly with depth; the
//! required fast size grows much more slowly.
//!
//! Run: `cargo bench --bench fig13_variants`

use sentinel_hm::figures::fig13_variants;
use sentinel_hm::util::bench::time_it;
use sentinel_hm::util::table::{fmt_bytes, Table};

fn main() {
    let t = time_it(1, || fig13_variants(12));
    t.report("fig13 (5 variants x fast-size search)");

    let rows = fig13_variants(12);
    println!("\n=== Fig 13 — peak memory vs min fast size (ResNet variants) ===");
    let mut table = Table::new(vec!["model", "peak memory", "min fast size", "fast/peak"]);
    for (m, peak, fast) in &rows {
        table.row(vec![
            m.clone(),
            fmt_bytes(*peak),
            fmt_bytes(*fast),
            format!("{:.0}%", 100.0 * *fast as f64 / *peak as f64),
        ]);
    }
    table.print();

    // Shape: peak grows monotonically; fast/peak ratio does not grow.
    let first_ratio = rows[0].2 as f64 / rows[0].1 as f64;
    let last_ratio = rows.last().unwrap().2 as f64 / rows.last().unwrap().1 as f64;
    println!(
        "\npaper: fast size grows much more slowly than peak memory\n\
         measured: fast/peak {:.2} (ResNet-20) → {:.2} (ResNet-110)",
        first_ratio, last_ratio
    );
    assert!(
        last_ratio <= first_ratio + 0.05,
        "required fast share must not grow with depth"
    );
}
