//! Bench: Fig. 10 — overall performance of Sentinel vs IAL vs the
//! fast-memory-only system at fast = 20% of peak, on all five models.
//!
//! Expected shape (paper): Sentinel within 8% of fast-only everywhere;
//! IAL loses 17% on average (up to 32%); Sentinel beats IAL by ~18%.
//!
//! Run: `cargo bench --bench fig10_overall`

use sentinel_hm::figures::{fig10_overall, fig10_table, RUN_STEPS};
use sentinel_hm::util::bench::time_it;

fn main() {
    let t = time_it(3, || fig10_overall(RUN_STEPS));
    t.report("fig10 (5 models x 3 policies)");

    let rows = fig10_overall(RUN_STEPS);
    println!("\n=== Fig 10 — normalized training throughput (fast = 20% of peak) ===");
    fig10_table(&rows).print();

    let sent_worst = rows.iter().map(|r| r.sentinel_norm).fold(f64::INFINITY, f64::min);
    let ial_avg = rows.iter().map(|r| r.ial_norm).sum::<f64>() / rows.len() as f64;
    let adv = rows
        .iter()
        .map(|r| r.sentinel_norm / r.ial_norm)
        .sum::<f64>()
        / rows.len() as f64;
    println!(
        "\npaper: Sentinel ≥ 0.92 everywhere; IAL avg 0.83; Sentinel/IAL ≈ 1.18\n\
         measured: Sentinel worst {sent_worst:.3}; IAL avg {ial_avg:.3}; \
         Sentinel/IAL avg {adv:.3}"
    );
    assert!(sent_worst > 0.85, "Fig 10 regression: Sentinel worst {sent_worst}");
    assert!(adv > 1.05, "Fig 10 regression: advantage {adv}");
}
