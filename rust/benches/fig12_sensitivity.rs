//! Bench: Fig. 12 — sensitivity of Sentinel to the fast-memory size
//! (10%–60% of peak memory consumption, all five models).
//!
//! Expected shape (paper): at 60% no model loses anything; between 20%
//! and 40% at most ~8% variance; larger fast memory never hurts.
//!
//! Run: `cargo bench --bench fig12_sensitivity`

use sentinel_hm::figures::{fig12_sensitivity, RUN_STEPS};
use sentinel_hm::util::bench::time_it;
use sentinel_hm::util::table::Table;

fn main() {
    let pcts = [10u32, 20, 30, 40, 60];
    let t = time_it(2, || fig12_sensitivity(&pcts, RUN_STEPS));
    t.report("fig12 (5 models x 5 sizes)");

    let rows = fig12_sensitivity(&pcts, RUN_STEPS);
    println!("\n=== Fig 12 — normalized throughput vs fast-memory size ===");
    let mut table = Table::new(vec!["model", "10%", "20%", "30%", "40%", "60%"]);
    for (m, series) in &rows {
        let mut row = vec![m.clone()];
        for (_, v) in series {
            row.push(format!("{v:.3}"));
        }
        table.row(row);
    }
    table.print();

    // Shape assertions: 60% column ≈ 1.0; 20→40% variance small.
    for (m, series) in &rows {
        let at = |p: u32| series.iter().find(|(pc, _)| *pc == p).unwrap().1;
        assert!(at(60) > 0.95, "{m}: 60% must be ≈ fast-only, got {}", at(60));
        let var = (at(40) - at(20)).abs();
        println!("{m}: |perf(40%) - perf(20%)| = {var:.3} (paper: ≤ 0.08)");
    }
}
