//! Bench: fault injection & recovery — what the fault hook costs the
//! healthy path (armed-but-empty plan vs no plan at all), and what a
//! faulted fleet costs end to end (injection, seal invalidation and
//! re-convergence, crash displacement, plus the fault-free twin the API
//! layer runs for the slowdown baseline).
//!
//! Run: `cargo bench --bench fault_recovery`

use sentinel_hm::api::{json, Admission, Autoscale, FaultSpec, FleetSpec};
use sentinel_hm::util::bench::time_it;

fn fleet(tenants: usize, faults: Option<FaultSpec>) -> FleetSpec {
    let mut s = FleetSpec::new()
        .tenants(tenants)
        .rate_per_s(2.0)
        .machines(2)
        .machine_fast_bytes(2 << 30)
        .admission(Admission::Queue)
        .autoscale(Autoscale::default())
        .threads(1)
        .seed(7);
    if let Some(f) = faults {
        s = s.faults(f);
    }
    s
}

fn main() {
    // Warm the workload, trace, and solo-baseline caches so the numbers
    // measure the fleet and fault drivers, not graph construction.
    fleet(16, None).run().expect("warm-up fleet");

    let mut summary = json::Obj::new().field_str("bench", "fault_recovery");

    let spec = fleet(200, None);
    let t = time_it(3, || spec.run().expect("fault-free fleet"));
    t.report("fleet 200 jobs, no fault plan");
    summary = summary.field_f64("fleet_200t_fault_free_ns", t.median_ns as f64);

    // Armed but quiet: the per-step fault hook plus the fault-free twin
    // — the price of *asking* for the degradation report.
    let spec = fleet(200, Some(FaultSpec::new().rate(0.0)));
    let t = time_it(3, || spec.run().expect("armed-but-empty fleet"));
    t.report("fleet 200 jobs, armed but empty plan (hook + twin)");
    summary = summary.field_f64("fleet_200t_armed_empty_ns", t.median_ns as f64);

    let spec = fleet(200, Some(FaultSpec::new().rate(0.05).crashes(true)));
    let t = time_it(3, || spec.run().expect("faulted fleet"));
    t.report("fleet 200 jobs, rate 0.05 with crashes (inject + recover + twin)");
    summary = summary.field_f64("fleet_200t_faulted_ns", t.median_ns as f64);

    // Shape sanity: the faulted run injected, recovered, and measured
    // its slowdown against the twin.
    let out = spec.run().expect("faulted fleet");
    let report = out.faults.expect("plan armed");
    assert!(report.injected > 0, "rate 0.05 over 200 jobs injects something");
    assert!(report.slowdown_vs_fault_free.is_some());
    summary = summary
        .field_u64("faults_injected", report.injected)
        .field_f64("mean_recovery_steps", report.mean_recovery_steps());

    println!("\n{}", summary.end());
}
