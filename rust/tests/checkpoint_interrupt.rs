//! Graceful-interrupt checkpointing.
//!
//! The interrupt flag is **process-global** (it mirrors a signal
//! handler's one bit of state), so these tests live in their own test
//! binary and serialize on a mutex: one pending interrupt must never
//! leak into a neighboring test.
//!
//! * An interrupted run with checkpointing configured halts with
//!   [`SimError::Interrupted`], leaves a loadable final checkpoint, and
//!   resuming from it reproduces the uninterrupted run bit for bit.
//! * The same holds for a fleet run.
//! * Without checkpointing configured, a pending interrupt is inert —
//!   the run completes normally (the boundary hook is never consulted).

use std::fs;
use std::path::PathBuf;
use std::sync::Mutex;

use sentinel_hm::api::{Admission, FleetSpec, PolicyKind, RunSpec, SimError};
use sentinel_hm::dnn::zoo::Model;
use sentinel_hm::sim::{clear_interrupt, load_checkpoint, request_interrupt};

/// Serializes every test in this binary around the process-global
/// interrupt flag.
static SERIAL: Mutex<()> = Mutex::new(());

/// Fresh per-test scratch directory under the system temp dir.
fn tdir(tag: &str) -> PathBuf {
    let d =
        std::env::temp_dir().join(format!("sentinel-ckpt-intr-{}-{}", tag, std::process::id()));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(&d).unwrap();
    d
}

fn solo() -> RunSpec {
    RunSpec::for_model(Model::Dcgan).policy(PolicyKind::Lru).fast_pct(30).steps(8)
}

#[test]
fn solo_interrupt_parks_in_a_checkpoint_and_resume_matches() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    clear_interrupt();
    let dir = tdir("solo");
    let base = solo().run().unwrap().to_json();

    // `checkpoint_dir` alone means interrupt-only checkpointing
    // (every = 0): nothing is written until the interrupt lands.
    request_interrupt();
    let err = solo().checkpoint_dir(&dir).run_checkpointed().unwrap_err();
    let SimError::Interrupted { checkpoint } = err else {
        clear_interrupt();
        panic!("expected Interrupted, got {err:?}");
    };
    clear_interrupt();
    let ck = load_checkpoint(&checkpoint).expect("the final checkpoint is well-formed");
    assert!(
        ck.progress >= 1 && ck.progress < 8,
        "interrupt parked mid-run, not at an end (progress {})",
        ck.progress
    );

    let resumed = solo().resume_from(&checkpoint).run_checkpointed().unwrap().to_json();
    assert_eq!(base, resumed, "resume after interrupt diverged from the uninterrupted run");
    let _ = fs::remove_dir_all(&dir);
}

fn fleet() -> FleetSpec {
    FleetSpec::new()
        .tenants(8)
        .rate_per_s(2.0)
        .machines(2)
        .machine_fast_bytes(3 << 30)
        .admission(Admission::Queue)
        .threads(1)
        .seed(17)
}

#[test]
fn fleet_interrupt_parks_in_a_checkpoint_and_resume_matches() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    clear_interrupt();
    let dir = tdir("fleet");
    let base = fleet().run().unwrap().to_json();

    request_interrupt();
    let err = fleet().checkpoint_dir(&dir).run_checkpointed().unwrap_err();
    let SimError::Interrupted { checkpoint } = err else {
        clear_interrupt();
        panic!("expected Interrupted, got {err:?}");
    };
    clear_interrupt();
    assert!(checkpoint.exists(), "final fleet checkpoint written");

    let resumed = fleet().resume_from(&checkpoint).run_checkpointed().unwrap().to_json();
    assert_eq!(base, resumed, "fleet resume after interrupt diverged");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn pending_interrupt_without_checkpointing_is_inert() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    clear_interrupt();
    let base = solo().run().unwrap().to_json();
    request_interrupt();
    let out = solo().run_checkpointed();
    clear_interrupt();
    assert_eq!(base, out.unwrap().to_json(), "uncheckpointed run must ignore the flag");
}
