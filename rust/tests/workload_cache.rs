//! Contention coverage for the process-wide workload cache
//! (`api/workload.rs`): same-key racers must block on exactly one
//! build, distinct keys must build independently, and the miss counter
//! must be an exact build counter under both patterns.
//!
//! Lives in its own integration binary so its global-counter deltas
//! cannot race other test files' cache traffic (each test binary is a
//! separate process); the tests within still serialize on a lock.

use std::sync::{Arc, Barrier, Mutex, MutexGuard};
use std::thread;

use sentinel_hm::api::{shared_workload, workload_cache_stats, Workload};
use sentinel_hm::dnn::zoo::Model;

static TEST_LOCK: Mutex<()> = Mutex::new(());

fn serialized() -> MutexGuard<'static, ()> {
    TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Fan `n` threads through `shared_workload`, all released by one
/// barrier so the first requests genuinely race.
fn race(
    n: usize,
    key: impl Fn(usize) -> (Model, u64) + Send + Sync + 'static,
) -> Vec<Arc<Workload>> {
    let barrier = Arc::new(Barrier::new(n));
    let key = Arc::new(key);
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let barrier = Arc::clone(&barrier);
            let key = Arc::clone(&key);
            thread::spawn(move || {
                let (model, seed) = key(i);
                barrier.wait();
                shared_workload(model, seed)
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
}

#[test]
fn same_key_racers_block_on_one_build() {
    let _guard = serialized();
    let before = workload_cache_stats();
    let workloads = race(8, |_| (Model::Dcgan, 0xC0117E57));
    // Every racer got the same Arc.
    for w in &workloads[1..] {
        assert!(
            Arc::ptr_eq(&workloads[0], w),
            "same-key racers must share one workload"
        );
    }
    let after = workload_cache_stats();
    assert_eq!(
        after.misses - before.misses,
        1,
        "8 same-key racers must trigger exactly one build"
    );
    assert_eq!(
        after.hits - before.hits,
        7,
        "the 7 losers of the build race count as hits"
    );
}

#[test]
fn distinct_keys_build_independently_in_parallel() {
    let _guard = serialized();
    let before = workload_cache_stats();
    let workloads = race(8, |i| (Model::Dcgan, 0xD15_000 + i as u64));
    // Eight distinct keys → eight builds, no waiting-as-hit.
    let after = workload_cache_stats();
    assert_eq!(after.misses - before.misses, 8, "one build per distinct key");
    assert_eq!(after.hits - before.hits, 0);
    for (i, a) in workloads.iter().enumerate() {
        for b in &workloads[i + 1..] {
            assert!(!Arc::ptr_eq(a, b), "distinct keys must not alias");
        }
    }
    // Re-requesting any of them is now a pure hit.
    let again = shared_workload(Model::Dcgan, 0xD15_000);
    assert!(Arc::ptr_eq(&workloads[0], &again));
    let final_stats = workload_cache_stats();
    assert_eq!(final_stats.misses - before.misses, 8);
    assert_eq!(final_stats.hits - before.hits, 1);
}

#[test]
fn mixed_contention_keeps_the_build_counter_exact() {
    let _guard = serialized();
    let before = workload_cache_stats();
    // 12 threads over 3 distinct keys (4 racers each).
    let workloads = race(12, |i| (Model::Dcgan, 0xABC_000 + (i % 3) as u64));
    let after = workload_cache_stats();
    assert_eq!(after.misses - before.misses, 3, "one build per distinct key");
    assert_eq!(after.hits - before.hits, 9);
    for i in 0..12 {
        assert!(
            Arc::ptr_eq(&workloads[i], &workloads[i % 3]),
            "thread {i} must share its key group's workload"
        );
    }
}
