//! The sealed-schedule equivalence proof: [`Engine::run`] with
//! steady-state sealing enabled (the default — record two steady steps,
//! seal a `CompiledSchedule`, replay the remainder as O(1) deltas) must
//! produce **bit-identical** `TrainResult`s to the same engine with
//! sealing disabled (the pure live compiled loop), for every policy in
//! the registry.
//!
//! Three parts:
//! * an exhaustive grid over `PolicyKind::all()` × {DCGAN, ResNet_v1-32}
//!   × fast-pct {15, 20, 35} (the ISSUE-4 acceptance matrix), with step
//!   counts long enough for every steady policy to actually seal;
//! * a property test over random fast sizes, step counts and seeds; and
//! * a cluster case (in `sim::cluster` terms) where priority
//!   arbitration invalidates a tenant's sealed schedule mid-run and the
//!   tenant provably re-seals afterwards.

use std::sync::Arc;

use sentinel_hm::api::PolicyKind;
use sentinel_hm::dnn::zoo::Model;
use sentinel_hm::dnn::{ModelGraph, StepTrace, Workload};
use sentinel_hm::mem::{DataObject, ObjectId};
use sentinel_hm::sim::cluster::{run_cluster, Arbitration, ClusterTenant};
use sentinel_hm::sim::engine::StaticPolicy;
use sentinel_hm::sim::{
    CompiledTrace, Engine, EngineConfig, Machine, MachineSpec, Policy, Tier, TrainResult,
};
use sentinel_hm::util::prop::check;
use sentinel_hm::PAGE_SIZE;

const MODELS: [Model; 2] = [Model::Dcgan, Model::ResNetV1 { depth: 32 }];

/// Exact (bit-level for floats) equality of two results. The seal
/// metadata (`steady_from_step` / `sealed_steps`) is intentionally
/// excluded — it *describes which tier executed*, and differs between
/// the arms by construction.
fn assert_bit_identical(a: &TrainResult, b: &TrainResult, ctx: &str) {
    assert_eq!(a.policy, b.policy, "{ctx}: policy");
    assert_eq!(a.model, b.model, "{ctx}: model");
    assert_eq!(
        a.total_time_ns.to_bits(),
        b.total_time_ns.to_bits(),
        "{ctx}: total_time_ns {} vs {}",
        a.total_time_ns,
        b.total_time_ns
    );
    assert_eq!(a.peak_fast_bytes, b.peak_fast_bytes, "{ctx}: peak_fast_bytes");
    assert_eq!(a.peak_total_bytes, b.peak_total_bytes, "{ctx}: peak_total_bytes");
    assert_eq!(a.pages_migrated_in, b.pages_migrated_in, "{ctx}: pages_in");
    assert_eq!(a.pages_migrated_out, b.pages_migrated_out, "{ctx}: pages_out");
    assert_eq!(a.alloc_spills, b.alloc_spills, "{ctx}: alloc_spills");
    assert_eq!(a.steps.len(), b.steps.len(), "{ctx}: step count");
    for (sa, sb) in a.steps.iter().zip(&b.steps) {
        assert_eq!(sa.step, sb.step, "{ctx}: step index");
        assert_eq!(
            sa.time_ns.to_bits(),
            sb.time_ns.to_bits(),
            "{ctx}: step {} time {} vs {}",
            sa.step,
            sa.time_ns,
            sb.time_ns
        );
        assert_eq!(sa.pages_in, sb.pages_in, "{ctx}: step {} pages_in", sa.step);
        assert_eq!(sa.pages_out, sb.pages_out, "{ctx}: step {} pages_out", sa.step);
    }
}

fn run_arm(
    seal: bool,
    g: &ModelGraph,
    trace: &StepTrace,
    kind: PolicyKind,
    fast_bytes: u64,
    steps: u32,
) -> TrainResult {
    let spec = kind.machine_spec(g, trace, fast_bytes);
    let mut cfg = kind.engine_config(steps);
    cfg.seal_steady = seal;
    let engine = Engine::new(cfg);
    let mut machine = Machine::new(spec);
    let mut policy = kind.construct(g, trace, spec);
    engine.run(g, trace, &mut machine, policy.as_mut())
}

fn check_equivalence(
    g: &ModelGraph,
    trace: &StepTrace,
    kind: PolicyKind,
    fast_bytes: u64,
    steps: u32,
    ctx: &str,
) -> TrainResult {
    let sealed = run_arm(true, g, trace, kind, fast_bytes, steps);
    let live = run_arm(false, g, trace, kind, fast_bytes, steps);
    assert_eq!(live.steady_from_step, None, "{ctx}: live arm must not seal");
    assert_eq!(live.sealed_steps, 0, "{ctx}: live arm must not seal");
    assert_bit_identical(&sealed, &live, ctx);
    sealed
}

#[test]
fn sealed_replay_is_bit_identical_across_registry_grid() {
    for model in MODELS {
        let g = model.build(1);
        let trace = StepTrace::from_graph(&g);
        let peak = model.peak_memory_target();
        for kind in PolicyKind::all() {
            for pct in [15u64, 20, 35] {
                let fast = peak * pct / 100;
                let ctx = format!("{} / {} / fast={pct}%", model.name(), kind.name());
                // 20 steps: room for Sentinel's tuning window plus a
                // sealable steady tail on every grid point.
                let sealed = check_equivalence(&g, &trace, kind, fast, 20, &ctx);
                // The static references have constant decision streams:
                // if even they failed to seal, the sealed arm silently
                // ran fully live and the grid would prove nothing.
                if matches!(kind, PolicyKind::FastOnly | PolicyKind::SlowOnly) {
                    assert_eq!(
                        sealed.steady_from_step,
                        Some(2),
                        "{ctx}: static policies must seal after two records"
                    );
                    assert_eq!(sealed.sealed_steps, 18, "{ctx}");
                }
            }
        }
    }
}

#[test]
fn sealed_replay_equivalence_property() {
    // Random fast sizes (including degenerate slivers), step counts and
    // seeds. DCGAN only: the property runs many cases.
    let g_cache: Vec<(u64, ModelGraph, StepTrace)> = [3u64, 11]
        .iter()
        .map(|&seed| {
            let g = Model::Dcgan.build(seed);
            let t = StepTrace::from_graph(&g);
            (seed, g, t)
        })
        .collect();
    let peak = Model::Dcgan.peak_memory_target();
    check("sealed replay ≡ live replay", 24, |tc| {
        let (_, g, trace) = &g_cache[tc.range(0, 1) as usize];
        let kinds = PolicyKind::all();
        let kind = kinds[tc.range(0, (kinds.len() - 1) as u64) as usize];
        // 5%..=60% of reported peak, and 2..=14 steps.
        let pct = tc.range(5, 60);
        let steps = tc.range(2, 14) as u32;
        let fast = (peak * pct / 100).max(1);
        let ctx = format!("prop: {} fast={pct}% steps={steps}", kind.name());
        check_equivalence(g, trace, kind, fast, steps, &ctx);
    });
}

/// A policy that places everything slow and, from `from_step` on, keeps
/// queueing an unfinishable promotion — a deterministic memory-pressure
/// faucet (the promotion lane stalls on fast capacity at every layer)
/// that switches on at a step of our choosing. Never steady, so its own
/// behavior stays on the live loop.
struct PressureFrom {
    from_step: u32,
    target: ObjectId,
    pages: u64,
    step: u32,
}

impl Policy for PressureFrom {
    fn name(&self) -> &str {
        "pressure-from"
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn place(&mut self, _obj: &DataObject, _m: &Machine) -> Tier {
        Tier::Slow
    }

    fn step_start(&mut self, step: u32, _m: &mut Machine, _g: &ModelGraph) {
        self.step = step;
    }

    fn layer_start(&mut self, _layer: u32, m: &mut Machine, _g: &ModelGraph) {
        if self.step >= self.from_step {
            m.request_promote(self.target, self.pages);
        }
    }
}

/// Priority arbitration must invalidate a sealed tenant's schedule and
/// the tenant must re-seal afterwards.
///
/// Construction: the low-priority victim is a static fast-placing
/// tenant with an ample share — it seals at its step 2. The
/// high-priority aggressor runs everything from slow memory (slower
/// steps than the victim's fast ones, so the victim is sealed well
/// before the first review) and starts stalling its promotion lane at
/// step 6, producing pressure at every later review. Each preemption
/// resizes the victim's share → seal invalidated. When the aggressor
/// finishes, the victim's remaining steps re-converge and re-seal.
#[test]
fn priority_reshare_invalidates_and_reseals() {
    let g = Model::Dcgan.build(5);
    let spec_base = MachineSpec::paper_testbed(1 << 30);
    let workload = Arc::new(Workload::from_graph(g));
    let compiled = Arc::new(CompiledTrace::compile(
        &workload.graph,
        &workload.trace,
        spec_base.compute_gflops,
        1_000.0,
    ));

    // The biggest persistent object: promoting it into a sliver of fast
    // memory can never finish — a guaranteed stall.
    let target = workload
        .graph
        .objects
        .iter()
        .filter(|o| o.persistent)
        .max_by_key(|o| (o.pages(), o.id))
        .expect("graph has persistent objects");

    let victim_share = workload.graph.peak_live_bytes() * 2 / PAGE_SIZE * PAGE_SIZE;
    let aggressor_share = 4 * PAGE_SIZE;

    let tenant = |policy: Box<dyn Policy>, share: u64, priority: u32, steps: u32| {
        let mut spec = spec_base;
        spec.fast.capacity_bytes = share;
        ClusterTenant {
            workload: Arc::clone(&workload),
            compiled: Arc::clone(&compiled),
            policy,
            config: EngineConfig { steps, ..Default::default() },
            machine: Machine::new(spec),
            priority,
            share,
        }
    };

    let aggressor = tenant(
        Box::new(PressureFrom {
            from_step: 6,
            target: target.id,
            pages: target.pages(),
            step: 0,
        }),
        aggressor_share,
        1,
        12,
    );
    let victim = tenant(Box::new(StaticPolicy { tier: Tier::Fast }), victim_share, 0, 60);

    let results = run_cluster(vec![aggressor, victim], Arbitration::Priority);
    let (agg, vic) = (&results[0], &results[1]);

    assert_eq!(vic.result.steps.len(), 60);
    assert!(
        agg.preemptions_won >= 1,
        "aggressor pressure must trigger at least one preemption"
    );
    assert_eq!(agg.preemptions_won, vic.preemptions_suffered);
    assert!(
        vic.seal_invalidations >= 1,
        "a preemption must have dropped a live sealed schedule \
         (invalidations={}, segments={})",
        vic.seal_invalidations,
        vic.seal_segments
    );
    assert!(
        vic.seal_segments >= 2,
        "the victim must re-seal after invalidation (segments={})",
        vic.seal_segments
    );
    assert!(vic.result.sealed_steps > 0);
    assert_eq!(vic.result.steady_from_step, Some(2), "ample share seals at step 2");
    // Sealed or not, per-step accounting stays complete and consistent.
    let step_pages: u64 = vic.result.steps.iter().map(|s| s.pages_in + s.pages_out).sum();
    assert_eq!(step_pages, vic.result.pages_migrated_in + vic.result.pages_migrated_out);
    // The aggressor itself never seals: its pressure policy never
    // declares steadiness.
    assert_eq!(agg.result.steady_from_step, None);
    assert_eq!(agg.seal_segments, 0);
}

/// N=1 sanity at the sim level: a sealed single-tenant cluster must
/// match the sealed solo engine bit-for-bit (the api-level anchor lives
/// in `cluster_tenancy.rs`; this pins the sealing tier specifically).
#[test]
fn single_sealed_tenant_matches_solo_engine() {
    let w = Arc::new(Workload::from_graph(Model::Dcgan.build(7)));
    let (g, trace) = (&w.graph, &w.trace);
    let kind = PolicyKind::Lru;
    let fast = Model::Dcgan.peak_memory_target() / 5;
    let spec = kind.machine_spec(g, trace, fast);
    let cfg = kind.engine_config(12);
    let compiled = Arc::new(CompiledTrace::compile(
        g,
        trace,
        spec.compute_gflops,
        cfg.profiling_fault_ns,
    ));

    let mut m = Machine::new(spec);
    let mut p = kind.construct(g, trace, spec);
    let solo = Engine::new(cfg).run_compiled(g, &compiled, &mut m, p.as_mut());

    let tenants = vec![ClusterTenant {
        workload: Arc::clone(&w),
        compiled: Arc::clone(&compiled),
        policy: kind.construct(g, trace, spec),
        config: cfg,
        machine: Machine::new(spec),
        priority: 0,
        share: fast,
    }];
    let cluster = run_cluster(tenants, Arbitration::Priority);
    assert_bit_identical(&solo, &cluster[0].result, "N=1 sealed cluster");
    assert_eq!(solo.steady_from_step, cluster[0].result.steady_from_step);
    assert_eq!(solo.sealed_steps, cluster[0].result.sealed_steps);
}
