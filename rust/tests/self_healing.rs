//! Self-healing fleet invariants: transient faults, retry/backoff, the
//! promote-lane circuit breaker, and the SLO watchdog.
//!
//! * **Dormant bit-identity**: a transient plan whose events never fire
//!   (scheduled past the run's horizon) leaves the fleet bit-identical
//!   to an unfaulted run — arming the machinery costs nothing until an
//!   event actually lands.
//! * **Quiet watchdog**: an armed [`SloSpec`] with an unreachable
//!   target reports an all-zero ledger and the same tenant digest as a
//!   plain run; only the outcome JSON grows (the `slo` ledger and the
//!   per-machine `drained` flag), by design.
//! * **Determinism**: a fleet under transients + crashes *and* an
//!   enforcing watchdog is bit-identical run to run and across worker
//!   counts — every mitigation fires on per-machine step clocks.
//! * **End-to-end healing**: a flaky lane trips the breaker, the
//!   watchdog climbs its ladder (boost → throttle → live evacuation),
//!   and every job still finishes every step.
//! * **Resume equivalence**: a self-healing fleet killed at checkpoint
//!   boundaries and resumed reproduces the uninterrupted outcome bit
//!   for bit, ledger included.
//! * **Breaker property**: random op sequences against a shadow model
//!   of the documented state machine, plus the machine-level promotion
//!   gate.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use sentinel_hm::api::{
    json, shared_workload, Admission, Autoscale, FaultSpec, FleetSpec, PolicyKind, SloSpec,
    Workload,
};
use sentinel_hm::dnn::zoo::Model;
use sentinel_hm::mem::ObjectId;
use sentinel_hm::sim::migration::{BREAKER_COOLDOWN_STEPS, BREAKER_TRIP_THRESHOLD};
use sentinel_hm::sim::{
    run_fleet, Arbitration, BreakerState, CircuitBreaker, ClusterTenant, CompiledTrace, FaultKind,
    FaultPlan, FleetArrival, FleetConfig, FleetSimResult, Machine, SloPolicy, Tier,
};

/// A t=0 job offer with an optional solo baseline for SLO tracking.
fn arrival(
    id: u64,
    w: &Arc<Workload>,
    compiled: &Arc<CompiledTrace>,
    kind: PolicyKind,
    demand: u64,
    peak: u64,
    steps: u32,
    solo_step_ns: f64,
) -> FleetArrival {
    let w = Arc::clone(w);
    let compiled = Arc::clone(compiled);
    FleetArrival {
        id,
        arrival_ns: 0.0,
        demand_bytes: demand,
        peak_bytes: peak,
        priority: 0,
        solo_step_ns,
        build: Box::new(move |share| {
            let spec = kind.machine_spec(&w.graph, &w.trace, share);
            ClusterTenant {
                policy: kind.construct(&w.graph, &w.trace, spec),
                config: kind.engine_config(steps),
                machine: Machine::new(spec),
                priority: 0,
                share,
                workload: w,
                compiled,
            }
        }),
    }
}

fn dcgan_parts(kind: PolicyKind, steps: u32) -> (Arc<Workload>, Arc<CompiledTrace>) {
    let w = shared_workload(Model::Dcgan, 5);
    let cfg = kind.engine_config(steps);
    let spec = kind.machine_spec(&w.graph, &w.trace, 1);
    let compiled = Arc::new(CompiledTrace::compile(
        &w.graph,
        &w.trace,
        spec.compute_gflops,
        cfg.profiling_fault_ns,
    ));
    (w, compiled)
}

fn config(machines: usize, fast: u64, threads: usize) -> FleetConfig {
    FleetConfig {
        machines,
        machine_fast_bytes: fast,
        arbitration: Arbitration::StaticPartition,
        admission: Admission::Queue,
        autoscale: None,
        threads,
        faults: None,
        slo: None,
    }
}

/// Bitwise equality of the per-departure observables two runs share.
fn assert_departures_identical(a: &FleetSimResult, b: &FleetSimResult, ctx: &str) {
    assert_eq!(a.completed.len(), b.completed.len(), "{ctx}: departure count");
    for (x, y) in a.completed.iter().zip(&b.completed) {
        assert_eq!(x.tenant_id, y.tenant_id, "{ctx}: departure order");
        assert_eq!(x.machine, y.machine, "{ctx}: job {} machine", x.tenant_id);
        assert_eq!(
            x.finish_ns.to_bits(),
            y.finish_ns.to_bits(),
            "{ctx}: job {} finish_ns {} vs {}",
            x.tenant_id,
            x.finish_ns,
            y.finish_ns
        );
        assert_eq!(
            x.result.result.total_time_ns.to_bits(),
            y.result.result.total_time_ns.to_bits(),
            "{ctx}: job {} total_time_ns",
            x.tenant_id
        );
    }
}

/// A transient plan whose only event sits far past the run's horizon
/// never fires — and an armed-but-dormant plan must leave every
/// departure bit-identical to an unfaulted run, with an all-zero
/// transient ledger in the report.
#[test]
fn dormant_transient_plan_leaves_fleet_bit_identical() {
    let kind = PolicyKind::Lru;
    let (w, compiled) = dcgan_parts(kind, 4);
    let fast = Model::Dcgan.peak_memory_target() / 8;
    let run = |faults: Option<FaultPlan>| {
        let jobs: Vec<FleetArrival> = (0..3)
            .map(|i| arrival(i, &w, &compiled, kind, fast / 2, fast, 4, 0.0))
            .collect();
        let mut cfg = config(2, fast, 1);
        cfg.faults = faults;
        run_fleet(jobs, cfg).expect("pool intact")
    };
    let base = run(None);
    assert!(base.faults.is_none(), "unarmed runs carry no report");
    let armed = run(Some(FaultPlan::new().push(
        0,
        100_000,
        FaultKind::MigrationTimeout { jitter: 0 },
    )));
    let report = armed.faults.as_ref().expect("armed runs carry a report");
    assert_eq!(report.injected, 0, "the horizon event never fired");
    assert_eq!(report.timeouts, 0);
    assert_eq!(report.flaky_windows, 0);
    assert_eq!(report.retries, 0);
    assert_eq!(report.breaker_trips, 0);
    assert_departures_identical(&base, &armed, "dormant plan");
}

fn churn(threads: usize) -> FleetSpec {
    FleetSpec::new()
        .tenants(8)
        .rate_per_s(2.0)
        .machines(2)
        .machine_fast_bytes(3 << 30)
        .admission(Admission::Queue)
        .autoscale(Autoscale::default())
        .threads(threads)
        .seed(17)
}

/// An armed watchdog with an unreachable target: the ledger is present
/// and all zeros, the tenant digest matches the plain run (round
/// bounding never changes per-machine interleaving), and only the JSON
/// surface grows.
#[test]
fn quiet_watchdog_reports_zero_ledger_and_matches_plain_digest() {
    let plain = churn(1).run().unwrap();
    assert!(plain.slo.is_none(), "unarmed runs carry no ledger");
    let plain_json = plain.to_json();
    assert!(!plain_json.contains("\"slo\""));
    assert!(!plain_json.contains("\"drained\""));

    let quiet = churn(1).slo(SloSpec::new().target_p99(1.0e9)).run().unwrap();
    let ledger = quiet.slo.expect("armed runs carry a ledger");
    assert_eq!(ledger.violations, 0, "unreachable target: {ledger:?}");
    assert_eq!(ledger.boosts + ledger.throttles + ledger.evacuations + ledger.drains, 0);
    assert_eq!(quiet.tenants_digest(), plain.tenants_digest(), "watchdog perturbed tenants");
    let quiet_json = quiet.to_json();
    assert!(json::is_valid(&quiet_json), "{quiet_json}");
    assert!(quiet_json.contains("\"slo\""));
    assert!(quiet_json.contains("\"drained\""));
}

fn self_healing_churn(threads: usize) -> FleetSpec {
    churn(threads)
        .faults(FaultSpec::new().rate(0.15).crashes(true))
        .slo(SloSpec::new().target_p99(1.5).window_events(2))
}

/// Same seed + same faulted spec + same enforcing watchdog ⇒
/// bit-identical outcome JSON (mitigation ledger included) and tenant
/// digest, run to run and for any worker count.
#[test]
fn self_healing_fleet_is_deterministic_across_runs_and_worker_counts() {
    let baseline = self_healing_churn(1).run().unwrap();
    let base_json = baseline.to_json();
    assert!(json::is_valid(&base_json), "{base_json}");
    let report = baseline.faults.as_ref().expect("plan armed");
    assert!(
        report.injected > 0,
        "rate 0.15 over this run must inject something (got {base_json})"
    );
    baseline.slo.expect("watchdog armed: ledger present");
    assert_eq!(
        base_json,
        self_healing_churn(1).run().unwrap().to_json(),
        "re-run drifted"
    );
    for threads in [4, 8] {
        let out = self_healing_churn(threads).run().unwrap();
        assert_eq!(base_json, out.to_json(), "{threads} workers drifted");
        assert_eq!(
            baseline.tenants_digest(),
            out.tenants_digest(),
            "{threads} workers: tenant table drifted"
        );
    }
}

/// The full loop, end to end: a flaky promote lane on the co-tenanted
/// machine trips the circuit breaker; the victim's slowdown violates
/// the SLO; the watchdog climbs its ladder through throttling to a
/// live evacuation; and every job still completes every step — on any
/// worker count, bit for bit.
#[test]
fn self_healing_loop_heals_end_to_end() {
    let kind = PolicyKind::Lru;
    let steps = 12u32;
    let (w, compiled) = dcgan_parts(kind, steps);
    let fast = Model::Dcgan.peak_memory_target() / 8;
    let run = |threads: usize| {
        // Placement: job 0 (60% demand) takes machine 0; jobs 1 and 2
        // (30% each) co-locate on machine 1 — the machine the flaky
        // window opens on. Job 1's absurd solo baseline keeps its
        // slowdown above any target, so the watchdog must act.
        let jobs = vec![
            arrival(0, &w, &compiled, kind, fast * 6 / 10, fast, steps, 0.0),
            arrival(1, &w, &compiled, kind, fast * 3 / 10, fast, steps, 1.0),
            arrival(2, &w, &compiled, kind, fast * 3 / 10, fast, steps, 0.0),
        ];
        let mut cfg = config(2, fast, threads);
        cfg.faults = Some(FaultPlan::new().push(
            1,
            2,
            FaultKind::FlakyLane { duration_steps: 6, fail_mask: 0b11_1111 },
        ));
        cfg.slo = Some(SloPolicy {
            target_p99: 2.0,
            window_events: 1,
            evacuate: true,
            warn_steps: 4,
        });
        run_fleet(jobs, cfg).expect("pool intact")
    };
    let r = run(1);
    assert_eq!(r.completed.len(), 3, "every job completes");
    for d in &r.completed {
        assert_eq!(
            d.result.result.steps.len(),
            steps as usize,
            "job {} ran every step through fault + mitigation",
            d.tenant_id
        );
    }
    let report = r.faults.as_ref().expect("plan armed");
    assert_eq!(report.flaky_windows, 1, "the window opened");
    assert_eq!(
        report.breaker_trips, 1,
        "six consecutive pre-drawn failures trip the breaker exactly once"
    );
    let ledger = r.slo.expect("ledger present");
    assert!(ledger.violations >= 3, "the victim kept violating: {ledger:?}");
    assert!(ledger.throttles >= 1, "rung 1 throttled the co-tenant: {ledger:?}");
    assert!(ledger.evacuations >= 1, "rung 2 moved the victim: {ledger:?}");
    // The same scenario on 4 workers: identical bits, identical ledger.
    let r4 = run(4);
    assert_departures_identical(&r, &r4, "4 workers");
    assert_eq!(ledger, r4.slo.expect("ledger present"), "4 workers: ledger drifted");
    assert_eq!(
        report.breaker_trips,
        r4.faults.as_ref().unwrap().breaker_trips,
        "4 workers: breaker drifted"
    );
}

/// Fresh per-test scratch directory under the system temp dir.
fn tdir(tag: &str) -> PathBuf {
    let d =
        std::env::temp_dir().join(format!("sentinel-self-healing-{}-{}", tag, std::process::id()));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(&d).unwrap();
    d
}

/// All checkpoint files in `dir`, sorted by progress.
fn ckpts(dir: &Path) -> Vec<PathBuf> {
    let mut v: Vec<PathBuf> = fs::read_dir(dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().map_or(false, |x| x == "ckpt"))
        .collect();
    v.sort();
    v
}

/// A self-healing fleet (transients, crashes, enforcing watchdog with
/// evacuation) checkpointed every other event round: resuming from each
/// checkpoint — including rounds after evacuations and drains —
/// reproduces the uninterrupted outcome bit for bit, ledger included.
#[test]
fn self_healing_fleet_resume_matches_uninterrupted() {
    let dir = tdir("resume");
    let baseline = self_healing_churn(1).run().unwrap();
    let base = baseline.to_json();
    let ckpt_run = self_healing_churn(1)
        .checkpoint_every(2)
        .checkpoint_dir(&dir)
        .run_checkpointed()
        .unwrap();
    assert_eq!(base, ckpt_run.to_json(), "writing checkpoints perturbed the run");
    let files = ckpts(&dir);
    assert!(!files.is_empty(), "fleet run wrote no checkpoints");
    for f in &files {
        let resumed = self_healing_churn(1).resume_from(f).run_checkpointed().unwrap();
        assert_eq!(base, resumed.to_json(), "resume from {} diverged", f.display());
        assert_eq!(baseline.tenants_digest(), resumed.tenants_digest());
    }
    let _ = fs::remove_dir_all(&dir);
}

/// Scripted walk through every documented breaker transition.
#[test]
fn breaker_walks_the_documented_state_machine() {
    let mut b = CircuitBreaker::new();
    assert_eq!(b.state(), BreakerState::Closed);
    assert!(b.allows_promotions());
    // One short of the threshold, then a success: the streak resets.
    for step in 0..u64::from(BREAKER_TRIP_THRESHOLD - 1) {
        assert!(!b.record_failure(step), "streak below threshold must not trip");
    }
    b.record_success();
    // A full streak trips exactly on the threshold'th failure.
    for step in 10..10 + u64::from(BREAKER_TRIP_THRESHOLD - 1) {
        assert!(!b.record_failure(step));
    }
    let trip_step = 10 + u64::from(BREAKER_TRIP_THRESHOLD - 1);
    assert!(b.record_failure(trip_step), "threshold'th consecutive failure trips");
    assert_eq!(b.state(), BreakerState::Open);
    assert!(!b.allows_promotions());
    // Open: failures are ignored, polls before the cooldown do nothing.
    assert!(!b.record_failure(trip_step + 1));
    assert!(!b.poll(trip_step + BREAKER_COOLDOWN_STEPS - 1));
    assert_eq!(b.state(), BreakerState::Open);
    // Cooldown elapses: half-open, probe traffic flows.
    assert!(b.poll(trip_step + BREAKER_COOLDOWN_STEPS));
    assert_eq!(b.state(), BreakerState::HalfOpen);
    assert!(b.allows_promotions());
    // A failed probe re-opens immediately (single failure, no streak).
    let reopen_step = trip_step + BREAKER_COOLDOWN_STEPS;
    assert!(b.record_failure(reopen_step), "failed probe re-opens");
    assert_eq!(b.state(), BreakerState::Open);
    assert!(b.poll(reopen_step + BREAKER_COOLDOWN_STEPS));
    // A landed probe closes the breaker for good.
    b.record_success();
    assert_eq!(b.state(), BreakerState::Closed);
    assert!(b.allows_promotions());
}

/// Property: random op sequences against a shadow model of the
/// documented state machine — the breaker and the model never disagree,
/// and `allows_promotions` is always `state != Open`.
#[test]
fn breaker_matches_shadow_model_on_random_op_sequences() {
    // Seeded LCG (same constants as the repo's other property tests).
    let mut rng_state = 0x5E1F_CAFE_u64;
    let mut rng = move || {
        rng_state = rng_state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        rng_state >> 33
    };
    for _case in 0..64 {
        let mut b = CircuitBreaker::new();
        // Shadow model: (state, streak, probe_at).
        let mut state = BreakerState::Closed;
        let mut streak = 0u32;
        let mut probe_at = 0u64;
        let mut step = 0u64;
        for _op in 0..200 {
            step += rng() % 3;
            match rng() % 3 {
                0 => {
                    let tripped = b.record_failure(step);
                    let model_trip = match state {
                        BreakerState::Closed => {
                            streak += 1;
                            if streak >= BREAKER_TRIP_THRESHOLD {
                                state = BreakerState::Open;
                                streak = 0;
                                probe_at = step + BREAKER_COOLDOWN_STEPS;
                                true
                            } else {
                                false
                            }
                        }
                        BreakerState::HalfOpen => {
                            state = BreakerState::Open;
                            probe_at = step + BREAKER_COOLDOWN_STEPS;
                            true
                        }
                        BreakerState::Open => false,
                    };
                    assert_eq!(tripped, model_trip, "trip mismatch at step {step}");
                }
                1 => {
                    b.record_success();
                    match state {
                        BreakerState::Closed => streak = 0,
                        BreakerState::HalfOpen => {
                            state = BreakerState::Closed;
                            streak = 0;
                        }
                        BreakerState::Open => {}
                    }
                }
                _ => {
                    let probed = b.poll(step);
                    let model_probe = state == BreakerState::Open && step >= probe_at;
                    if model_probe {
                        state = BreakerState::HalfOpen;
                    }
                    assert_eq!(probed, model_probe, "poll mismatch at step {step}");
                }
            }
            assert_eq!(b.state(), state, "state diverged at step {step}");
            assert_eq!(
                b.allows_promotions(),
                state != BreakerState::Open,
                "gate must mirror the state"
            );
        }
    }
}

/// The machine-level promotion gate an open breaker drives: while shut,
/// promotion requests are dropped on the floor (no promote-lane
/// traffic); reopened, the same request queues pages again.
#[test]
fn promotion_gate_drops_requests_while_blocked() {
    let kind = PolicyKind::Lru;
    let w = shared_workload(Model::Dcgan, 5);
    let spec = kind.machine_spec(&w.graph, &w.trace, Model::Dcgan.peak_memory_target() / 4);
    let mut m = Machine::new(spec);
    let obj = ObjectId(0);
    m.alloc(obj, 8, Tier::Slow);
    assert!(!m.promotions_blocked(), "machines start with the gate open");
    m.set_promotions_blocked(true);
    assert!(m.promotions_blocked());
    m.request_promote(obj, 8);
    assert_eq!(m.pending_in_pages(), 0, "a shut gate queues nothing");
    m.set_promotions_blocked(false);
    m.request_promote(obj, 8);
    assert_eq!(m.pending_in_pages(), 8, "a reopened gate queues the retry");
}
