//! Tenancy invariants for the multi-tenant co-scheduling subsystem.
//!
//! * **N=1 anchor**: a single-tenant cluster replay is *bit-identical*
//!   to the solo `Engine::run` path (same spec construction, same
//!   compiled trace, same per-layer replay function), for every managed
//!   policy family.
//! * **Share containment**: under the static-partition arbitration, a
//!   tenant's fast-memory occupancy never exceeds its arbitrated share
//!   — checked as a property over random tenant mixes.
//! * **Arbitration sanity**: all three policies run 2+ tenants to
//!   completion, conserve total share, and report valid JSON with
//!   per-tenant slowdown-vs-solo.

use sentinel_hm::api::{
    json, Arbitration, ClusterSpec, PolicyKind, RunSpec, TenantSpec,
};
use sentinel_hm::dnn::zoo::Model;
use sentinel_hm::sim::TrainResult;
use sentinel_hm::util::prop::check;

/// Exact (bit-level for floats) equality of two results.
fn assert_bit_identical(a: &TrainResult, b: &TrainResult, ctx: &str) {
    assert_eq!(a.policy, b.policy, "{ctx}: policy");
    assert_eq!(a.model, b.model, "{ctx}: model");
    assert_eq!(
        a.total_time_ns.to_bits(),
        b.total_time_ns.to_bits(),
        "{ctx}: total_time_ns {} vs {}",
        a.total_time_ns,
        b.total_time_ns
    );
    assert_eq!(a.peak_fast_bytes, b.peak_fast_bytes, "{ctx}: peak_fast_bytes");
    assert_eq!(a.peak_total_bytes, b.peak_total_bytes, "{ctx}: peak_total_bytes");
    assert_eq!(a.pages_migrated_in, b.pages_migrated_in, "{ctx}: pages_in");
    assert_eq!(a.pages_migrated_out, b.pages_migrated_out, "{ctx}: pages_out");
    assert_eq!(a.alloc_spills, b.alloc_spills, "{ctx}: alloc_spills");
    assert_eq!(a.steps.len(), b.steps.len(), "{ctx}: step count");
    for (sa, sb) in a.steps.iter().zip(&b.steps) {
        assert_eq!(
            sa.time_ns.to_bits(),
            sb.time_ns.to_bits(),
            "{ctx}: step {} time {} vs {}",
            sa.step,
            sa.time_ns,
            sb.time_ns
        );
        assert_eq!(sa.pages_in, sb.pages_in, "{ctx}: step {} pages_in", sa.step);
        assert_eq!(sa.pages_out, sb.pages_out, "{ctx}: step {} pages_out", sa.step);
    }
}

#[test]
fn single_tenant_cluster_is_bit_identical_to_solo_engine() {
    let fast = Model::Dcgan.peak_memory_target() / 5;
    for kind in [
        PolicyKind::Sentinel(Default::default()),
        PolicyKind::StaticInterval(6),
        PolicyKind::Ial,
        PolicyKind::Lru,
    ] {
        let solo = RunSpec::for_model(Model::Dcgan)
            .policy(kind)
            .steps(12)
            .fast_bytes(fast)
            .run()
            .unwrap();
        let cluster = ClusterSpec::new()
            .tenant(TenantSpec::for_model(Model::Dcgan).policy(kind))
            .fast_bytes(fast)
            .steps(12)
            .run()
            .unwrap();
        assert_eq!(cluster.tenants.len(), 1);
        let t = &cluster.tenants[0];
        let ctx = format!("N=1 cluster vs solo / {}", kind.name());
        assert_bit_identical(&solo.result, &t.result, &ctx);
        // The solo baseline inside the cluster is the same configuration,
        // so the reported slowdown is exactly 1.
        assert!(
            (t.slowdown_vs_solo - 1.0).abs() < 1e-12,
            "{ctx}: slowdown {}",
            t.slowdown_vs_solo
        );
        assert_eq!(t.contention_migrations, 0, "{ctx}: contention migrations");
        assert_eq!(t.warmup_steps, solo.warmup_steps, "{ctx}: warmup");
    }
}

#[test]
fn all_three_arbitrations_run_two_tenants_to_completion() {
    for arb in Arbitration::all() {
        let out = ClusterSpec::new()
            .tenant(TenantSpec::for_model(Model::Dcgan).priority(1))
            .tenant(
                TenantSpec::for_model(Model::ResNetV1 { depth: 32 })
                    .policy(PolicyKind::StaticInterval(8)),
            )
            .arbitration(arb)
            .fast_pct(20)
            .steps(10)
            .run()
            .unwrap();
        assert_eq!(out.tenants.len(), 2, "{arb}");
        let share_sum: u64 = out.tenants.iter().map(|t| t.share_final).sum();
        assert!(
            share_sum <= out.fast_bytes_total,
            "{arb}: shares {share_sum} exceed the machine's {}",
            out.fast_bytes_total
        );
        for t in &out.tenants {
            assert_eq!(t.result.steps.len(), 10, "{arb}/{}", t.model);
            assert_eq!(t.fast_occupancy_per_step.len(), 10, "{arb}/{}", t.model);
            // No tenant's capacity ever exceeds the whole machine, so
            // neither can its occupancy.
            assert!(t.result.peak_fast_bytes <= out.fast_bytes_total);
            assert!(t.solo_throughput > 0.0, "{arb}/{}: solo baseline ran", t.model);
        }
        let won: u64 = out.tenants.iter().map(|t| t.preemptions_won).sum();
        let lost: u64 = out.tenants.iter().map(|t| t.preemptions_suffered).sum();
        assert_eq!(won, lost, "{arb}: preemption bookkeeping");
        if arb != Arbitration::Priority {
            assert_eq!(won, 0, "{arb}: only the priority arbiter preempts");
            for t in &out.tenants {
                assert_eq!(t.share_initial, t.share_final, "{arb}: fixed shares");
            }
        }
        let j = out.to_json();
        assert!(json::is_valid(&j), "{arb}: {j}");
        assert!(j.contains("\"slowdown_vs_solo\""), "{arb}");
        assert!(j.contains("\"fast_occupancy_per_step\""), "{arb}");
    }
}

#[test]
fn static_partition_occupancy_never_exceeds_share_property() {
    check("per-tenant fast occupancy ≤ static share", 10, |g| {
        let n = g.range(2, 4) as usize;
        let steps = g.range(3, 6) as u32;
        let pct = g.range(10, 40) as u32;
        let mut cs = ClusterSpec::new().fast_pct(pct).steps(steps);
        for i in 0..n {
            let kind = match g.range(0, 2) {
                0 => PolicyKind::Lru,
                1 => PolicyKind::StaticInterval(g.range(2, 8) as u32),
                _ => PolicyKind::Ial,
            };
            cs = cs.tenant(
                TenantSpec::for_model(Model::Dcgan)
                    .policy(kind)
                    .priority(i as u32),
            );
        }
        let out = cs.run().unwrap();
        assert_eq!(out.tenants.len(), n);
        for t in &out.tenants {
            assert_eq!(
                t.share_initial, t.share_final,
                "static shares never move"
            );
            assert!(
                t.result.peak_fast_bytes <= t.share_initial,
                "{}: peak fast {} exceeds share {}",
                t.model,
                t.result.peak_fast_bytes,
                t.share_initial
            );
            for &occ in &t.fast_occupancy_per_step {
                assert!(
                    occ <= t.share_initial,
                    "{}: occupancy {occ} exceeds share {}",
                    t.model,
                    t.share_initial
                );
            }
        }
    });
}

#[test]
fn priority_arbitration_moves_share_toward_higher_priority() {
    // Tight fast memory so the high-priority tenant feels pressure.
    let out = ClusterSpec::new()
        .tenant(TenantSpec::for_model(Model::Dcgan).priority(2))
        .tenant(TenantSpec::for_model(Model::Dcgan).priority(0))
        .arbitration(Arbitration::Priority)
        .fast_pct(10)
        .steps(8)
        .run()
        .unwrap();
    let hi = &out.tenants[0];
    let lo = &out.tenants[1];
    // Share can only flow low → high, never the other way.
    assert!(hi.share_final >= hi.share_initial, "high-priority share shrank");
    assert!(lo.share_final <= lo.share_initial, "low-priority share grew");
    assert_eq!(hi.preemptions_suffered, 0, "nothing outranks priority 2");
    assert_eq!(lo.preemptions_won, 0, "priority 0 cannot preempt");
    assert_eq!(hi.preemptions_won, lo.preemptions_suffered);
}
