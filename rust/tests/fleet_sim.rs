//! Fleet-layer invariants.
//!
//! * **Cluster anchor**: a single-machine, no-churn fleet (every job
//!   arriving at t=0, spill admission so nothing queues) is
//!   *bit-identical* to the equivalent [`ClusterSpec`] run — same
//!   shares, same interleaving, same per-step times, same
//!   slowdown-vs-solo (the baselines come from the same cache).
//! * **Determinism**: same seed + same spec ⇒ bit-identical outcome
//!   JSON and tenant digest, across repeated runs *and* across
//!   worker-thread counts (the per-round machine fan-out must not leak
//!   scheduling into results).
//! * **Admission containment**: under the static arbiter with reject or
//!   queue admission, no machine's committed demand or arbitrated share
//!   sum ever exceeds its fast tier — checked as a property over random
//!   job mixes.
//! * **Policy behavior**: queueing completes every job eventually,
//!   spilling admits every job immediately, autoscaling grows the pool
//!   under sustained pressure.

use sentinel_hm::api::{
    json, Admission, Autoscale, ClusterSpec, FleetJob, FleetSpec, JobClass, PolicyKind,
    TenantSpec,
};
use sentinel_hm::dnn::zoo::Model;
use sentinel_hm::sim::TrainResult;
use sentinel_hm::util::prop::check;

/// Exact (bit-level for floats) equality of two engine results.
fn assert_bit_identical(a: &TrainResult, b: &TrainResult, ctx: &str) {
    assert_eq!(a.policy, b.policy, "{ctx}: policy");
    assert_eq!(a.model, b.model, "{ctx}: model");
    assert_eq!(
        a.total_time_ns.to_bits(),
        b.total_time_ns.to_bits(),
        "{ctx}: total_time_ns {} vs {}",
        a.total_time_ns,
        b.total_time_ns
    );
    assert_eq!(a.peak_fast_bytes, b.peak_fast_bytes, "{ctx}: peak_fast_bytes");
    assert_eq!(a.pages_migrated_in, b.pages_migrated_in, "{ctx}: pages_in");
    assert_eq!(a.pages_migrated_out, b.pages_migrated_out, "{ctx}: pages_out");
    assert_eq!(a.alloc_spills, b.alloc_spills, "{ctx}: alloc_spills");
    assert_eq!(a.steps.len(), b.steps.len(), "{ctx}: step count");
    for (i, (sa, sb)) in a.steps.iter().zip(&b.steps).enumerate() {
        assert_eq!(
            sa.time_ns.to_bits(),
            sb.time_ns.to_bits(),
            "{ctx}: step {i} time {} vs {}",
            sa.time_ns,
            sb.time_ns
        );
    }
}

fn job(id: u64, arrival_ns: f64, model: Model, policy: PolicyKind, steps: u32) -> FleetJob {
    FleetJob {
        id,
        arrival_ns,
        model,
        policy,
        steps,
        priority: 0,
        class: JobClass::Training,
    }
}

/// A single-machine fleet with every job present from t=0 must replay
/// the cluster layer exactly: same shares, same virtual-clock
/// interleaving, same per-step times, same slowdowns.
#[test]
fn no_churn_single_machine_fleet_matches_cluster_run() {
    let fast = Model::Dcgan.peak_memory_target() * 3 / 10;
    let steps = 12u32; // == the fleet layer's canonical solo length

    let cluster = ClusterSpec::new()
        .tenant(TenantSpec::for_model(Model::Dcgan).policy(PolicyKind::Lru))
        .tenant(TenantSpec::for_model(Model::Dcgan).policy(PolicyKind::StaticInterval(4)))
        .fast_bytes(fast)
        .steps(steps)
        .run()
        .unwrap();

    let fleet = FleetSpec::new()
        .with_jobs(vec![
            job(0, 0.0, Model::Dcgan, PolicyKind::Lru, steps),
            job(1, 0.0, Model::Dcgan, PolicyKind::StaticInterval(4), steps),
        ])
        .machines(1)
        .machine_fast_bytes(fast)
        .admission(Admission::SpillToSlow)
        .threads(1)
        .run()
        .unwrap();

    assert_eq!(fleet.tenants.len(), cluster.tenants.len());
    for (f, c) in fleet.tenants.iter().zip(&cluster.tenants) {
        assert_eq!(f.join_ns.to_bits(), 0f64.to_bits(), "no-churn job joins at t=0");
        assert_eq!(f.machine, 0);
        assert_eq!(f.share_initial, c.share_initial, "{}: initial share", f.model);
        assert_eq!(f.share_final, c.share_final, "{}: final share", f.model);
        assert_bit_identical(&f.result, &c.result, &f.model);
        assert_eq!(
            f.slowdown_vs_solo.to_bits(),
            c.slowdown_vs_solo.to_bits(),
            "{}: slowdown {} vs {}",
            f.model,
            f.slowdown_vs_solo,
            c.slowdown_vs_solo
        );
    }
    // The fleet's finish times are the cluster's per-tenant clocks.
    let makespan: f64 = fleet.tenants.iter().map(|t| t.finish_ns).fold(0.0, f64::max);
    assert_eq!(makespan.to_bits(), cluster.makespan_ns().to_bits());
}

fn churn_spec(threads: usize) -> FleetSpec {
    FleetSpec::new()
        .tenants(8)
        .rate_per_s(2.0)
        .machines(2)
        .machine_fast_bytes(3 << 30)
        .admission(Admission::Queue)
        .threads(threads)
        .seed(17)
}

/// Same seed + same spec ⇒ bit-identical outcome, run to run and for
/// any worker count.
#[test]
fn fleet_outcome_is_deterministic_across_runs_and_thread_counts() {
    let baseline = churn_spec(1).run().unwrap();
    let base_json = baseline.to_json();
    assert!(json::is_valid(&base_json), "{base_json}");
    assert_eq!(base_json, churn_spec(1).run().unwrap().to_json(), "re-run drifted");
    for threads in [2, 8] {
        let out = churn_spec(threads).run().unwrap();
        assert_eq!(base_json, out.to_json(), "{threads} threads drifted");
        assert_eq!(
            baseline.tenants_digest(),
            out.tenants_digest(),
            "{threads} threads: tenant table drifted"
        );
    }
}

/// Under reject/queue admission the committed demand never exceeds a
/// machine's fast tier, and arbitration never hands out more share than
/// physically exists — over random job mixes.
#[test]
fn admission_never_oversubscribes_fast_memory() {
    check("admission containment", 6, |g| {
        let n_jobs = 1 + g.range(0, 3);
        let jobs: Vec<FleetJob> = (0..n_jobs)
            .map(|id| {
                let model = if g.bool(0.5) { Model::Dcgan } else { Model::MobileNet };
                let mut j = job(id, g.f64() * 1e8, model, PolicyKind::Lru, 1 + g.range(0, 1) as u32);
                if g.bool(0.5) {
                    j.class = JobClass::Inference;
                }
                j
            })
            .collect();
        let admission = if g.bool(0.5) { Admission::Reject } else { Admission::Queue };
        let fast = (g.range(300, 1200) as u64) << 20;
        let machines = 1 + g.range(0, 1) as usize;
        let out = FleetSpec::new()
            .with_jobs(jobs)
            .machines(machines)
            .machine_fast_bytes(fast)
            .admission(admission)
            .threads(1)
            .run()
            .unwrap();
        assert_eq!(
            out.completed + out.rejected,
            out.jobs_offered,
            "every job completes or is rejected"
        );
        if admission == Admission::Queue {
            assert_eq!(out.rejected, 0, "queueing never rejects");
        }
        for (i, m) in out.machines.iter().enumerate() {
            assert!(
                m.peak_committed_bytes <= fast,
                "machine {i}: committed {} exceeds fast {fast}",
                m.peak_committed_bytes
            );
            assert!(
                m.peak_share_bytes <= fast,
                "machine {i}: share sum {} exceeds fast {fast}",
                m.peak_share_bytes
            );
        }
        for t in &out.tenants {
            assert!(
                t.result.peak_fast_bytes <= fast,
                "job {}: fast residency {} exceeds the machine",
                t.id,
                t.result.peak_fast_bytes
            );
        }
    });
}

/// Spilling admits everything immediately even when the pool is
/// oversubscribed; shares still respect the physical tier.
#[test]
fn spill_admits_all_and_shares_stay_physical() {
    let fast = Model::Dcgan.peak_memory_target() / 4;
    let jobs: Vec<FleetJob> =
        (0..3).map(|id| job(id, 0.0, Model::Dcgan, PolicyKind::Lru, 2)).collect();
    let out = FleetSpec::new()
        .with_jobs(jobs)
        .machines(1)
        .machine_fast_bytes(fast)
        .admission(Admission::SpillToSlow)
        .threads(1)
        .run()
        .unwrap();
    assert_eq!(out.completed, 3);
    assert_eq!(out.rejected, 0);
    assert!(out.spilled >= 1, "the pool was oversubscribed");
    assert!(out.machines[0].peak_committed_bytes > fast);
    assert!(out.machines[0].peak_share_bytes <= fast);
}

/// Sustained pressure grows the pool; later jobs land on the new
/// machines.
#[test]
fn autoscale_grows_the_pool_under_sustained_pressure() {
    let fast = (700u64) << 20; // one 614 MB training DCGAN fills a machine
    let jobs: Vec<FleetJob> = (0..4)
        .map(|id| job(id, id as f64 * 1e6, Model::Dcgan, PolicyKind::Lru, 2))
        .collect();
    let out = FleetSpec::new()
        .with_jobs(jobs)
        .machines(1)
        .machine_fast_bytes(fast)
        .admission(Admission::Queue)
        .autoscale(Autoscale {
            min_machines: 1,
            max_machines: 4,
            grow_above: 0.5,
            shrink_below: -1.0, // never shrink in this test
            sustain_events: 1,
        })
        .threads(1)
        .run()
        .unwrap();
    assert_eq!(out.completed, 4);
    assert!(out.scale_ups >= 1, "pool never grew: {}", out.to_json());
    assert!(out.machines.len() > 1);
    assert!(
        out.tenants.iter().any(|t| t.machine > 0),
        "no job ever landed on a grown machine"
    );
}
