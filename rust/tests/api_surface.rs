//! Surface tests for the unified experiment API: policy-name round
//! trips, spec validation, JSON well-formedness, and the determinism
//! guarantee of the parallel batch runner.

use sentinel_hm::api::{json, run_batch, PolicyKind, RunSpec, SpecError};
use sentinel_hm::dnn::zoo::Model;

#[test]
fn policy_names_round_trip_through_from_str() {
    for kind in PolicyKind::all() {
        let name = kind.name();
        let parsed: PolicyKind = name.parse().unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(parsed, kind, "{name} must round-trip");
        assert_eq!(parsed.name(), name);
    }
}

#[test]
fn unknown_policy_error_lists_valid_names() {
    let err = "definitely-not-a-policy".parse::<PolicyKind>().unwrap_err();
    for expected in ["sentinel", "ial", "lru", "fast-only", "slow-only", "mi:"] {
        assert!(err.contains(expected), "error should list '{expected}': {err}");
    }
}

#[test]
fn validation_rejects_zero_steps() {
    let err = RunSpec::for_model(Model::Dcgan).steps(0).validate().unwrap_err();
    assert_eq!(err, SpecError::ZeroSteps);
    assert!(RunSpec::for_model(Model::Dcgan).steps(0).run().is_err());
}

#[test]
fn validation_rejects_unknown_model() {
    let err = RunSpec::model("alexnet-4096").validate().unwrap_err();
    assert_eq!(err, SpecError::UnknownModel("alexnet-4096".into()));
    // The error message points at the zoo.
    assert!(err.to_string().contains("resnet32"), "{err}");
}

#[test]
fn validation_rejects_fast_larger_than_slow_tier() {
    let err = RunSpec::for_model(Model::Dcgan)
        .fast_bytes(1 << 30)
        .slow_bytes(1 << 20)
        .validate()
        .unwrap_err();
    assert_eq!(
        err,
        SpecError::FastExceedsSlow { fast: 1 << 30, slow: 1 << 20 }
    );
}

#[test]
fn validation_rejects_degenerate_fast_sizes() {
    assert!(matches!(
        RunSpec::for_model(Model::Dcgan).fast_bytes(0).validate(),
        Err(SpecError::BadFastSize(_))
    ));
    assert!(matches!(
        RunSpec::for_model(Model::Dcgan).fast_fraction(0.0).validate(),
        Err(SpecError::BadFastSize(_))
    ));
    assert!(matches!(
        RunSpec::for_model(Model::Dcgan).fast_fraction(1.5).validate(),
        Err(SpecError::BadFastSize(_))
    ));
    assert!(matches!(
        RunSpec::for_model(Model::Dcgan).fast_pct(0).validate(),
        Err(SpecError::BadFastSize(_))
    ));
    // Fast-only ignores the fast size, so 0 is fine there.
    assert!(RunSpec::for_model(Model::Dcgan)
        .policy(PolicyKind::FastOnly)
        .fast_bytes(0)
        .validate()
        .is_ok());
}

#[test]
fn outcomes_serialize_to_wellformed_json() {
    for policy in [
        PolicyKind::Sentinel(Default::default()),
        PolicyKind::Ial,
        PolicyKind::FastOnly,
    ] {
        let out = RunSpec::for_model(Model::Dcgan)
            .policy(policy)
            .steps(6)
            .run()
            .expect("run");
        let doc = out.to_json();
        assert!(json::is_valid(&doc), "invalid JSON for {}: {doc}", out.policy);
        assert!(doc.contains("\"model\":\"DCGAN\""), "{doc}");
        assert!(doc.contains("\"per_step\":["), "{doc}");
    }
}

#[test]
fn sentinel_outcome_carries_tuning_metadata() {
    let out = RunSpec::for_model(Model::Dcgan).steps(10).run().expect("run");
    assert_eq!(out.policy, "sentinel");
    assert!(out.cases.is_some());
    assert!(out.chosen_mi.is_some());
    assert!(out.profile.is_some());
    assert!(out.warmup_steps >= 2, "profiling + ≥1 measured candidate");
    let fast_only = RunSpec::for_model(Model::Dcgan)
        .policy(PolicyKind::FastOnly)
        .steps(4)
        .run()
        .expect("run");
    assert!(fast_only.cases.is_none());
    assert_eq!(fast_only.warmup_steps, 1);
}

/// The acceptance bar: a 4-thread `run_batch` over a compare-style grid
/// must be bit-identical to the serial path (JSON uses shortest-round-
/// trip float formatting, so string equality is bit equality).
#[test]
fn run_batch_is_bit_identical_to_serial() {
    let models = [Model::ResNetV1 { depth: 32 }, Model::Dcgan];
    let policies = [
        PolicyKind::FastOnly,
        PolicyKind::Sentinel(Default::default()),
        PolicyKind::Ial,
    ];
    let specs: Vec<RunSpec> = models
        .iter()
        .flat_map(|&m| {
            policies
                .iter()
                .map(move |&p| RunSpec::for_model(m).fast_pct(20).policy(p).steps(8))
        })
        .collect();
    let serial: Vec<String> = specs
        .iter()
        .map(|s| s.run().expect("serial run").to_json())
        .collect();
    let parallel = run_batch(specs, 4);
    assert_eq!(serial.len(), parallel.len());
    for (i, (s, p)) in serial.iter().zip(&parallel).enumerate() {
        let p = p.as_ref().expect("parallel run").to_json();
        assert_eq!(s, &p, "spec {i} diverged between serial and 4-thread batch");
    }
}

#[test]
fn named_and_enum_specs_agree() {
    let by_name = RunSpec::model("dcgan")
        .policy(PolicyKind::FastOnly)
        .steps(3)
        .run()
        .expect("by-name run");
    let by_enum = RunSpec::for_model(Model::Dcgan)
        .policy(PolicyKind::FastOnly)
        .steps(3)
        .run()
        .expect("by-enum run");
    assert_eq!(by_name.to_json(), by_enum.to_json());
}
