//! Fault-injection and recovery invariants.
//!
//! * **Fault-free bit-identity**: arming a [`FaultSpec`] whose plan is
//!   empty (rate 0) must leave solo, cluster, and fleet runs
//!   bit-identical to runs with no spec at all — the fault hook may
//!   cost the healthy path nothing, not even an RNG draw.
//! * **Determinism**: a *faulted* fleet is bit-identical run to run and
//!   across worker-thread counts — faults fire on per-machine step
//!   clocks, not wall clocks.
//! * **Recovery**: when every fault lands early in a cluster run, every
//!   recovery closes as a genuine re-seal (all survivors holding sealed
//!   schedules again) and no tenant loses a step.
//! * **Crash displacement**: a tenant displaced by a machine crash
//!   re-enters through admission, resumes from its completed-step
//!   count, and finishes with exactly its requested step total.

use std::sync::Arc;

use sentinel_hm::api::{
    json, shared_workload, Admission, Autoscale, ClusterSpec, FaultSpec, FleetSpec, PolicyKind,
    RunSpec, TenantSpec, Workload,
};
use sentinel_hm::dnn::zoo::Model;
use sentinel_hm::sim::{
    run_fleet, Arbitration, ClusterTenant, CompiledTrace, FaultKind, FaultPlan, FleetArrival,
    FleetConfig, Machine, TrainResult,
};

/// Exact (bit-level for floats) equality of two engine results.
fn assert_bit_identical(a: &TrainResult, b: &TrainResult, ctx: &str) {
    assert_eq!(
        a.total_time_ns.to_bits(),
        b.total_time_ns.to_bits(),
        "{ctx}: total_time_ns {} vs {}",
        a.total_time_ns,
        b.total_time_ns
    );
    assert_eq!(a.peak_fast_bytes, b.peak_fast_bytes, "{ctx}: peak_fast_bytes");
    assert_eq!(a.pages_migrated_in, b.pages_migrated_in, "{ctx}: pages_in");
    assert_eq!(a.pages_migrated_out, b.pages_migrated_out, "{ctx}: pages_out");
    assert_eq!(a.alloc_spills, b.alloc_spills, "{ctx}: alloc_spills");
    assert_eq!(a.steps.len(), b.steps.len(), "{ctx}: step count");
    for (i, (sa, sb)) in a.steps.iter().zip(&b.steps).enumerate() {
        assert_eq!(
            sa.time_ns.to_bits(),
            sb.time_ns.to_bits(),
            "{ctx}: step {i} time {} vs {}",
            sa.time_ns,
            sb.time_ns
        );
    }
}

/// An armed-but-quiet solo run (zero-rate spec → empty plan) must be
/// bit-identical to a run with no spec, and its report must say so:
/// nothing injected, slowdown exactly 1.
#[test]
fn armed_but_empty_faults_leave_solo_run_bit_identical() {
    let spec = || RunSpec::for_model(Model::Dcgan).fast_pct(30).steps(10);
    let base = spec().run().unwrap();
    assert!(base.faults.is_none(), "unarmed runs carry no report");
    let armed = spec().faults(FaultSpec::new().rate(0.0)).run().unwrap();
    let report = armed.faults.as_ref().expect("armed runs carry a report");
    assert_eq!(report.injected, 0);
    assert_eq!(
        report.slowdown_vs_fault_free.map(f64::to_bits),
        Some(1f64.to_bits()),
        "an empty plan's twin is the run itself"
    );
    assert_bit_identical(&armed.result, &base.result, "solo");
    // The report is the only JSON difference, by design.
    assert!(!base.to_json().contains("\"faults\""));
    assert!(armed.to_json().contains("\"faults\""));
}

#[test]
fn armed_but_empty_faults_leave_cluster_run_bit_identical() {
    let fast = Model::Dcgan.peak_memory_target() * 3 / 10;
    let spec = || {
        ClusterSpec::new()
            .tenant(TenantSpec::for_model(Model::Dcgan).policy(PolicyKind::Lru))
            .tenant(TenantSpec::for_model(Model::Dcgan).policy(PolicyKind::StaticInterval(4)))
            .fast_bytes(fast)
            .steps(10)
    };
    let base = spec().run().unwrap();
    assert!(base.faults.is_none());
    let armed = spec().faults(FaultSpec::new().rate(0.0)).run().unwrap();
    let report = armed.faults.as_ref().expect("armed runs carry a report");
    assert_eq!(report.injected, 0);
    assert_eq!(armed.makespan_ns().to_bits(), base.makespan_ns().to_bits());
    assert_eq!(armed.tenants.len(), base.tenants.len());
    for (a, b) in armed.tenants.iter().zip(&base.tenants) {
        assert_bit_identical(&a.result, &b.result, &a.model);
    }
}

#[test]
fn armed_but_empty_faults_leave_fleet_run_bit_identical() {
    let spec = || {
        FleetSpec::new()
            .tenants(8)
            .rate_per_s(2.0)
            .machines(2)
            .machine_fast_bytes(3 << 30)
            .admission(Admission::Queue)
            .threads(1)
            .seed(17)
    };
    let base = spec().run().unwrap();
    assert!(base.faults.is_none());
    // Crashes enabled but rate 0: still an empty plan.
    let armed = spec().faults(FaultSpec::new().rate(0.0).crashes(true)).run().unwrap();
    let report = armed.faults.as_ref().expect("armed runs carry a report");
    assert_eq!(report.injected, 0);
    assert_eq!(armed.tenants_digest(), base.tenants_digest());
    assert_eq!(armed.makespan_ns.to_bits(), base.makespan_ns.to_bits());
    assert!(!base.to_json().contains("\"faults\""));
    assert!(!base.to_json().contains("\"crashed\""));
    assert!(armed.to_json().contains("\"faults\""));
}

fn faulted_churn(threads: usize) -> FleetSpec {
    FleetSpec::new()
        .tenants(8)
        .rate_per_s(2.0)
        .machines(2)
        .machine_fast_bytes(3 << 30)
        .admission(Admission::Queue)
        .autoscale(Autoscale::default())
        .threads(threads)
        .seed(17)
        .faults(FaultSpec::new().rate(0.15).crashes(true))
}

/// Same seed + same faulted spec ⇒ bit-identical outcome JSON and
/// tenant digest, run to run and for any worker count. Faults fire on
/// per-machine cumulative-step clocks, which advance identically
/// however the pool is fanned out.
#[test]
fn faulted_fleet_is_deterministic_across_runs_and_worker_counts() {
    let baseline = faulted_churn(1).run().unwrap();
    let base_json = baseline.to_json();
    assert!(json::is_valid(&base_json), "{base_json}");
    let report = baseline.faults.as_ref().expect("plan armed");
    assert!(
        report.injected > 0,
        "rate 0.15 over this run must inject something (got {base_json})"
    );
    assert_eq!(base_json, faulted_churn(1).run().unwrap().to_json(), "re-run drifted");
    for threads in [4, 8] {
        let out = faulted_churn(threads).run().unwrap();
        assert_eq!(base_json, out.to_json(), "{threads} workers drifted");
        assert_eq!(
            baseline.tenants_digest(),
            out.tenants_digest(),
            "{threads} workers: tenant table drifted"
        );
    }
}

/// Every fault lands in the first 6 machine steps of a 48-machine-step
/// cluster run, so every recovery must close as a genuine re-seal (all
/// survivors sealed again) rather than by the run ending — and no
/// tenant loses a step. Static-interval tenants re-seal two steady
/// steps after any disruption, which makes the property sharp.
#[test]
fn early_faults_all_reseal_and_every_tenant_completes() {
    let fast = Model::Dcgan.peak_memory_target() * 3 / 10;
    let steps = 24u32;
    let out = ClusterSpec::new()
        .tenant(TenantSpec::for_model(Model::Dcgan).policy(PolicyKind::StaticInterval(4)))
        .tenant(TenantSpec::for_model(Model::Dcgan).policy(PolicyKind::StaticInterval(3)))
        .fast_bytes(fast)
        .steps(steps)
        .faults(FaultSpec::new().rate(0.6).horizon_steps(6))
        .run()
        .unwrap();
    let report = out.faults.as_ref().expect("plan armed");
    assert!(report.injected >= 1, "rate 0.6 over 6 steps draws something");
    assert_eq!(
        report.recovery_steps.len() as u64,
        report.injected,
        "every fault's recovery is accounted (no crashes in a cluster draw)"
    );
    assert_eq!(
        report.reseals, report.injected,
        "with ~40 machine steps after the last fault, every recovery must \
         close with a full re-seal, not the run ending"
    );
    for t in &out.tenants {
        assert_eq!(t.result.steps.len(), steps as usize, "{}: no step lost", t.model);
    }
}

fn arrival(
    id: u64,
    w: &Arc<Workload>,
    compiled: &Arc<CompiledTrace>,
    kind: PolicyKind,
    demand: u64,
    peak: u64,
    steps: u32,
) -> FleetArrival {
    let w = Arc::clone(w);
    let compiled = Arc::clone(compiled);
    FleetArrival {
        id,
        arrival_ns: 0.0,
        demand_bytes: demand,
        peak_bytes: peak,
        priority: 0,
        solo_step_ns: 0.0,
        build: Box::new(move |share| {
            let spec = kind.machine_spec(&w.graph, &w.trace, share);
            ClusterTenant {
                policy: kind.construct(&w.graph, &w.trace, spec),
                config: kind.engine_config(steps),
                machine: Machine::new(spec),
                priority: 0,
                share,
                workload: w,
                compiled,
            }
        }),
    }
}

/// A surgical crash on machine 0 displaces its resident; under queue
/// admission the tenant re-enters, waits for room on the survivor,
/// resumes from its completed-step count, and finishes with exactly its
/// requested step total — no step lost, none repeated.
#[test]
fn crash_displaced_tenant_resumes_and_completes_every_step() {
    let kind = PolicyKind::Lru;
    let steps = 6u32;
    let w = shared_workload(Model::Dcgan, 5);
    let cfg = kind.engine_config(steps);
    let mspec = kind.machine_spec(&w.graph, &w.trace, 1);
    let compiled = Arc::new(CompiledTrace::compile(
        &w.graph,
        &w.trace,
        mspec.compute_gflops,
        cfg.profiling_fault_ns,
    ));
    let fast = Model::Dcgan.peak_memory_target() / 2;
    // Two t=0 jobs at 60% demand each: one per machine, and after the
    // crash the displaced one must queue until the survivor has room.
    let jobs = vec![
        arrival(0, &w, &compiled, kind, fast * 6 / 10, fast, steps),
        arrival(1, &w, &compiled, kind, fast * 6 / 10, fast, steps),
    ];
    let r = run_fleet(
        jobs,
        FleetConfig {
            machines: 2,
            machine_fast_bytes: fast,
            arbitration: Arbitration::StaticPartition,
            admission: Admission::Queue,
            autoscale: None,
            threads: 1,
            faults: Some(FaultPlan::new().push(0, 2, FaultKind::Crash)),
            slo: None,
        },
    )
    .expect("machine 1 survives the crash");
    assert_eq!(r.completed.len(), 2, "both jobs finish");
    for d in &r.completed {
        assert_eq!(
            d.result.result.steps.len(),
            steps as usize,
            "job {}: exactly the requested step total across crash + resume",
            d.tenant_id
        );
    }
    let report = r.faults.as_ref().expect("plan configured");
    assert_eq!(report.crashes, 1);
    assert_eq!(report.tenants_displaced, 1);
    assert!(r.machines[0].crashed && r.machines[0].retired);
    assert!(!r.machines[1].crashed);
    let displaced = r
        .completed
        .iter()
        .find(|d| d.machine == 1 && d.join_ns > 0.0)
        .expect("the displaced tenant rejoined on the survivor");
    assert!(
        displaced.finish_ns > displaced.join_ns,
        "the resumed tenant did real work after rejoining"
    );
}
