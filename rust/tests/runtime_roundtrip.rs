//! Integration: the AOT artifacts load, compile and train through the
//! PJRT runtime — the full L1(Pallas)→L2(JAX)→L3(Rust) composition.
//!
//! Requires `make artifacts` to have run (the Makefile's `test` target
//! guarantees it); the tests are skipped with a notice otherwise.

use sentinel_hm::runtime::{literal_f32, trainer::synthetic_batch, MlpTrainer, Runtime};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts/ — run `make artifacts` first");
        None
    }
}

#[test]
fn artifacts_load_and_compile() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("load artifacts");
    assert_eq!(rt.platform(), "cpu");
    let mut names = rt.artifact_names();
    names.sort();
    for required in ["fwd_in", "fwd_hidden", "fwd_out", "loss_grad", "bwd_hidden"] {
        assert!(names.contains(&required), "missing {required}");
    }
}

#[test]
fn fwd_hidden_applies_relu() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("load artifacts");
    let m = rt.manifest.clone();
    // x = -1 everywhere, w = +1, b = 0 → pre-activation is negative →
    // relu output must be exactly zero.
    let x = literal_f32(&vec![-1.0; m.batch * m.dim], &[m.batch as i64, m.dim as i64]).unwrap();
    let w = literal_f32(&vec![1.0; m.dim * m.hidden], &[m.dim as i64, m.hidden as i64]).unwrap();
    let b = literal_f32(&vec![0.0; m.hidden], &[m.hidden as i64]).unwrap();
    let out = rt.run("fwd_in", &[x, w, b]).expect("run fwd_in");
    let h: Vec<f32> = out[0].to_vec().unwrap();
    assert!(h.iter().all(|&v| v == 0.0), "relu must clamp negatives");
}

#[test]
fn loss_grad_rows_sum_to_zero() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("load artifacts");
    let m = rt.manifest.clone();
    let (_, y) = synthetic_batch(&m, 3).unwrap();
    let logits = literal_f32(
        &(0..m.batch * m.classes)
            .map(|i| ((i * 37 % 101) as f32 / 50.0) - 1.0)
            .collect::<Vec<_>>(),
        &[m.batch as i64, m.classes as i64],
    )
    .unwrap();
    let out = rt.run("loss_grad", &[logits, y]).expect("run loss_grad");
    let loss: f32 = out[0].get_first_element().unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    let d: Vec<f32> = out[1].to_vec().unwrap();
    for row in d.chunks(m.classes) {
        let s: f32 = row.iter().sum();
        assert!(s.abs() < 1e-5, "softmax CE grad rows sum to 0, got {s}");
    }
}

#[test]
fn training_reduces_loss() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("load artifacts");
    let m = rt.manifest.clone();
    let mut trainer = MlpTrainer::new(&rt, 42).expect("init trainer");
    assert!(trainer.param_count() > 100_000, "non-trivial model");
    let (x, y) = synthetic_batch(&m, 0).unwrap();
    let (loss0, timing) = trainer.train_step(&x, &y, 0.05).expect("step");
    assert!(timing.total_ns() > 0);
    let mut loss_end = loss0;
    for i in 1..30 {
        let (l, _) = trainer.train_step(&x, &y, 0.05).expect("step");
        loss_end = l;
        let _ = i;
    }
    assert!(
        loss_end < loss0 * 0.7,
        "loss must decrease on a fixed batch: {loss0} → {loss_end}"
    );
}

#[test]
fn training_is_deterministic() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("load artifacts");
    let m = rt.manifest.clone();
    let (x, y) = synthetic_batch(&m, 7).unwrap();
    let mut t1 = MlpTrainer::new(&rt, 9).unwrap();
    let mut t2 = MlpTrainer::new(&rt, 9).unwrap();
    let (l1, _) = t1.train_step(&x, &y, 0.1).unwrap();
    let (l2, _) = t2.train_step(&x, &y, 0.1).unwrap();
    assert_eq!(l1, l2, "same seed + same data = same loss");
}
