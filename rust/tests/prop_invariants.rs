//! Property-based tests over the coordinator/simulator invariants, using
//! the in-tree `util::prop` harness (no proptest in the offline build).
//!
//! Invariants covered:
//! * machine capacity is never exceeded, residency never goes negative,
//!   and used-bytes accounting matches residency exactly under random
//!   alloc/free/promote/demote/exec sequences;
//! * migration lanes conserve pages (no page created or lost);
//! * migration plans only prefetch live, long-lived, pre-existing
//!   objects, and RS reservations are bounded;
//! * the short-lived pool never lends more than it reserved;
//! * the engine returns memory to the persistent baseline every step;
//! * dynamic (phase-changing) runs — objects resizing, appearing and
//!   disappearing between steps — never leak pages or exceed the fast
//!   share, with the divergence detector on or off.

use sentinel_hm::coordinator::plan::MigrationPlan;
use sentinel_hm::dnn::dynamic::{
    scale_non_persistent, DynamicKind, DynamicVariant, DynamicWorkload,
};
use sentinel_hm::dnn::graph::GraphBuilder;
use sentinel_hm::dnn::layer::LayerKind;
use sentinel_hm::dnn::{ModelGraph, StepTrace, TraceEvent};
use sentinel_hm::mem::{ObjectId, ShortLivedPool};
use sentinel_hm::sim::engine::StaticPolicy;
use sentinel_hm::sim::{Engine, EngineConfig, Machine, MachineSpec, Tier};
use sentinel_hm::util::prop::{check, Gen};
use sentinel_hm::PAGE_SIZE;

/// Random small graph: a few layers, random objects with consistent
/// lifetimes and accesses.
fn random_graph(g: &mut Gen) -> ModelGraph {
    let n_layers = g.range(2, 12) as u32;
    let mut b = GraphBuilder::new("prop", 4);
    for i in 0..n_layers {
        b.layer(LayerKind::Dense, format!("l{i}"), g.range(0, 1_000_000) as f64, false);
    }
    let n_objects = g.range(1, 60);
    for _ in 0..n_objects {
        let alloc = g.range(0, (n_layers - 1) as u64) as u32;
        let free = g.range(alloc as u64, (n_layers - 1) as u64) as u32;
        let size = g.range(16, 3 * PAGE_SIZE);
        if g.bool(0.15) {
            let h = b.persistent(size);
            for l in 0..n_layers {
                if g.bool(0.4) {
                    b.access(h, l, g.range(1, 20) as u32);
                }
            }
        } else {
            let h = b.object(size, alloc, free);
            for l in alloc..=free {
                if g.bool(0.6) {
                    b.access(h, l, g.range(1, 20) as u32);
                }
            }
        }
    }
    b.finish()
}

#[test]
fn machine_capacity_and_accounting_invariants() {
    check("machine invariants", 96, |g| {
        let cap_pages = g.range(1, 64);
        let spec = MachineSpec::paper_testbed(cap_pages * PAGE_SIZE);
        let mut m = Machine::new(spec);
        let mut live: Vec<(ObjectId, u64)> = Vec::new();
        let mut next_id = 0u32;
        for _ in 0..g.range(1, 200) {
            match g.range(0, 5) {
                0 => {
                    let pages = g.range(1, 8);
                    let pref = if g.bool(0.5) { Tier::Fast } else { Tier::Slow };
                    let id = ObjectId(next_id);
                    next_id += 1;
                    m.alloc(id, pages, pref);
                    live.push((id, pages));
                }
                1 => {
                    if !live.is_empty() {
                        let idx = g.range(0, live.len() as u64 - 1) as usize;
                        let (id, _) = live.swap_remove(idx);
                        m.free(id);
                    }
                }
                2 => {
                    if !live.is_empty() {
                        let idx = g.range(0, live.len() as u64 - 1) as usize;
                        let (id, pages) = live[idx];
                        m.request_promote(id, g.range(1, pages));
                    }
                }
                3 => {
                    if !live.is_empty() {
                        let idx = g.range(0, live.len() as u64 - 1) as usize;
                        let (id, pages) = live[idx];
                        m.request_demote(id, g.range(1, pages));
                    }
                }
                _ => {
                    m.exec(g.range(0, 100_000) as f64);
                }
            }
            // INVARIANT: fast usage within capacity.
            assert!(
                m.used_bytes(Tier::Fast) <= cap_pages * PAGE_SIZE,
                "fast over capacity"
            );
            // INVARIANT: accounting matches residency.
            let (mut fast, mut total) = (0u64, 0u64);
            for &(id, pages) in &live {
                let r = m.residency(id);
                assert!(r.alive);
                assert_eq!(r.pages_total, pages, "residency total drifted");
                assert!(r.pages_fast <= r.pages_total, "fast > total");
                fast += r.pages_fast;
                total += r.pages_total;
            }
            assert_eq!(m.used_bytes(Tier::Fast), fast * PAGE_SIZE);
            assert_eq!(
                m.used_bytes(Tier::Fast) + m.used_bytes(Tier::Slow),
                total * PAGE_SIZE,
                "pages created or lost"
            );
        }
    });
}

#[test]
fn lane_drain_completes_all_requests() {
    check("lane conservation", 64, |g| {
        let spec = MachineSpec::paper_testbed(u64::MAX);
        let mut m = Machine::new(spec);
        let n = g.range(1, 30) as u32;
        let mut total_pages = 0;
        for i in 0..n {
            let pages = g.range(1, 64);
            m.alloc(ObjectId(i), pages, Tier::Slow);
            m.request_promote(ObjectId(i), pages);
            total_pages += pages;
        }
        // Grant more than enough time: everything must arrive.
        m.exec((total_pages as f64 + 10.0) * m.ns_per_page() * 2.0);
        for i in 0..n {
            let r = m.residency(ObjectId(i));
            assert_eq!(r.pages_fast, r.pages_total, "promotion incomplete");
        }
        assert_eq!(m.stats.pages_in, total_pages);
        assert_eq!(m.pending_in_pages(), 0);
    });
}

#[test]
fn plan_invariants_hold_for_random_graphs() {
    check("plan invariants", 48, |g| {
        let graph = random_graph(g);
        let mi = g.range(1, graph.n_layers() as u64) as u32;
        let spec = MachineSpec::paper_testbed(1 << 30);
        let plan = MigrationPlan::build(&graph, mi, &spec);
        assert_eq!(plan.n_intervals, graph.n_layers().div_ceil(mi));
        // Prefetch entries: long-lived, existing before their interval.
        for (k, objs) in plan.prefetch.iter().enumerate() {
            for oid in objs {
                let o = &graph.objects[oid.index()];
                assert!(!o.is_short_lived());
                assert!(o.alloc_layer < k as u32 * mi);
            }
        }
        // Eviction entries: alive at that layer.
        for (l, objs) in plan.evict_after_layer.iter().enumerate() {
            for oid in objs {
                let o = &graph.objects[oid.index()];
                assert!(o.alive_in_layer(l as u32));
            }
        }
        // RS bounded by page-rounded short-lived total.
        let bound: u64 = graph
            .objects
            .iter()
            .filter(|o| o.is_short_lived())
            .map(|o| o.pages() * PAGE_SIZE)
            .sum();
        assert!(plan.max_rs_bytes() <= bound);
    });
}

#[test]
fn pool_never_overlends() {
    check("pool bounds", 96, |g| {
        let mut pool = ShortLivedPool::new(g.bool(0.5));
        let mut served: Vec<ObjectId> = Vec::new();
        let mut next = 0u32;
        for _ in 0..g.range(1, 100) {
            match g.range(0, 2) {
                0 => {
                    pool.begin_interval(g.range(0, 1 << 20));
                }
                1 => {
                    let id = ObjectId(next);
                    next += 1;
                    if pool.serve(id, g.range(1, 1 << 16)) {
                        served.push(id);
                    }
                }
                _ => {
                    if !served.is_empty() {
                        let idx = g.range(0, served.len() as u64 - 1) as usize;
                        pool.release(served.swap_remove(idx));
                    }
                }
            }
            assert!(
                pool.in_use_bytes() <= pool.reserved_bytes(),
                "pool lent more than reserved"
            );
        }
    });
}

#[test]
fn engine_returns_to_persistent_baseline_on_random_graphs() {
    check("engine baseline", 24, |g| {
        let graph = random_graph(g);
        let trace = StepTrace::from_graph(&graph);
        let mut m = Machine::new(MachineSpec::paper_testbed(u64::MAX));
        let e = sentinel_hm::sim::Engine::new(sentinel_hm::sim::EngineConfig {
            steps: 2,
            ..Default::default()
        });
        let r = e.run(
            &graph,
            &trace,
            &mut m,
            &mut sentinel_hm::sim::engine::StaticPolicy { tier: Tier::Fast },
        );
        assert_eq!(r.steps.len(), 2);
        let persistent: u64 = graph
            .objects
            .iter()
            .filter(|o| o.persistent)
            .map(|o| o.pages() * PAGE_SIZE)
            .sum();
        assert_eq!(
            m.used_bytes(Tier::Fast) + m.used_bytes(Tier::Slow),
            persistent,
            "non-persistent memory leaked across steps"
        );
    });
}

/// Three phases of one random graph: the base, a scaled twin (every
/// non-persistent object and the FLOPs grown by a random factor), and
/// a thinned twin in which a random subset of non-persistent objects
/// never materializes — the appear/disappear case a phase switch
/// induces mid-run.
fn phase_variants(g: &mut Gen, base: ModelGraph) -> Vec<DynamicVariant> {
    let scaled = scale_non_persistent(&base, 1.0 + g.range(1, 15) as f64 / 10.0);
    let scaled_trace = StepTrace::from_graph(&scaled);

    let thinned = base.clone();
    let mut thinned_trace = StepTrace::from_graph(&thinned);
    let mut dead = vec![false; thinned.objects.len()];
    for o in &thinned.objects {
        if !o.persistent && g.bool(0.3) {
            dead[o.id.index()] = true;
        }
    }
    for lt in &mut thinned_trace.layers {
        lt.events.retain(|ev| {
            let oid = match *ev {
                TraceEvent::Alloc(o) | TraceEvent::Free(o) => o,
                TraceEvent::Access { obj, .. } => obj,
            };
            !dead[oid.index()]
        });
    }

    let base_trace = StepTrace::from_graph(&base);
    vec![
        DynamicVariant { trace: base_trace, graph: base },
        DynamicVariant { trace: scaled_trace, graph: scaled },
        DynamicVariant { trace: thinned_trace, graph: thinned },
    ]
}

#[test]
fn dynamic_phase_changes_never_leak_pages_or_exceed_fast() {
    check("dynamic residency conservation", 24, |g| {
        let base = random_graph(g);
        let persistent: u64 = base
            .objects
            .iter()
            .filter(|o| o.persistent)
            .map(|o| o.pages() * PAGE_SIZE)
            .sum();
        let variants = phase_variants(g, base);
        let steps = g.range(4, 10) as u32;
        let plan: Vec<u32> = (0..steps).map(|_| g.range(0, 2) as u32).collect();
        let w = DynamicWorkload::from_parts(DynamicKind::VarBatch, 0.5, variants, plan);
        let cap = g.range(4, 128) * PAGE_SIZE;
        for detector in [false, true] {
            let mut m = Machine::new(MachineSpec::paper_testbed(cap));
            let e = Engine::new(EngineConfig { steps, ..Default::default() });
            let (r, d) =
                e.run_dynamic(&w, &mut m, &mut StaticPolicy { tier: Tier::Fast }, detector);
            assert_eq!(r.steps.len(), steps as usize);
            // INVARIANT: the fast share is a hard bound, whatever
            // appears or disappears between steps.
            assert!(
                r.peak_fast_bytes <= cap,
                "fast share exceeded: {} > {cap} (detector={detector})",
                r.peak_fast_bytes
            );
            // INVARIANT: every phase ends back at the persistent
            // baseline — objects that vanished from a later phase's
            // trace must not leave residue from an earlier one.
            assert_eq!(
                m.used_bytes(Tier::Fast) + m.used_bytes(Tier::Slow),
                persistent,
                "pages leaked across phase changes (detector={detector})"
            );
            if detector {
                assert_eq!(d.stale_steps, 0, "the detector leaves no stale exposure");
            }
        }
    });
}

#[test]
fn trace_events_are_consistent_for_random_graphs() {
    check("trace consistency", 48, |g| {
        let graph = random_graph(g);
        let trace = StepTrace::from_graph(&graph);
        // Every non-persistent object allocs exactly once and frees
        // exactly once; accesses only between them.
        let mut state = vec![0u8; graph.objects.len()]; // 0=unborn 1=live 2=dead
        for &p in &trace.persistent {
            state[p.index()] = 1;
        }
        for lt in &trace.layers {
            for ev in &lt.events {
                match *ev {
                    sentinel_hm::dnn::TraceEvent::Alloc(o) => {
                        assert_eq!(state[o.index()], 0, "double alloc");
                        state[o.index()] = 1;
                    }
                    sentinel_hm::dnn::TraceEvent::Access { obj, count } => {
                        assert_eq!(state[obj.index()], 1, "access while not live");
                        assert!(count > 0);
                    }
                    sentinel_hm::dnn::TraceEvent::Free(o) => {
                        assert_eq!(state[o.index()], 1, "free while not live");
                        state[o.index()] = 2;
                    }
                }
            }
        }
        for (i, o) in graph.objects.iter().enumerate() {
            if o.persistent {
                assert_eq!(state[i], 1);
            } else {
                assert_eq!(state[i], 2, "object never freed");
            }
        }
    });
}
