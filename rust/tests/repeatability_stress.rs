//! Seal-machine stress suite: push PR 4's seal/invalidate machinery off
//! its happy path with workloads that break the §2.1 repeatability
//! premise, and prove the online divergence detector's two contracts:
//!
//! 1. **Exact seal accounting.** On an adversarial two-phase workload
//!    the seal / invalidate / re-seal counts follow the phase schedule
//!    *exactly* — no spurious seals, no missed invalidations.
//! 2. **No stale replay, ever.** A detector-on run with sealing enabled
//!    is bit-identical to the same run with sealing disabled
//!    (`seal_steady = false`, the pure-live reference): every sealed
//!    delta the engine applied stands for exactly the live step it
//!    replaced, across every invalidate → re-seal cycle.
//!
//! Plus the tentpole's headline: with the detector ON, Sentinel's
//! slowdown-vs-fast-only on the variable-batch workload at the paper's
//! 20% fast fraction is strictly smaller than with the detector OFF
//! (trust the step-1 profile forever), and `variability = 0.0` remains
//! JSON-bit-identical to the static path across the policy registry.

use sentinel_hm::api::{PolicyKind, RunSpec, DEFAULT_SEED};
use sentinel_hm::dnn::dynamic::{
    scale_non_persistent, DynamicKind, DynamicVariant, DynamicWorkload,
};
use sentinel_hm::dnn::zoo::Model;
use sentinel_hm::dnn::StepTrace;
use sentinel_hm::sim::engine::StaticPolicy;
use sentinel_hm::sim::{
    DivergenceStats, Engine, EngineConfig, Machine, MachineSpec, Tier, TrainResult,
};

const RN32: Model = Model::ResNetV1 { depth: 32 };

/// An adversarial workload alternating A,B,A,B,… between two steady
/// phases, `phase_len` steps each. Phase B scales every non-persistent
/// object (and the FLOPs) by 1.4×, so its steps cannot be confused
/// with phase A's.
fn alternating(phases: usize, phase_len: usize) -> DynamicWorkload {
    let g = Model::Dcgan.build(7);
    let g2 = scale_non_persistent(&g, 1.4);
    let variants = vec![
        DynamicVariant { trace: StepTrace::from_graph(&g), graph: g },
        DynamicVariant { trace: StepTrace::from_graph(&g2), graph: g2 },
    ];
    let plan: Vec<u32> = (0..phases * phase_len)
        .map(|s| ((s / phase_len) % 2) as u32)
        .collect();
    DynamicWorkload::from_parts(DynamicKind::VarBatch, 0.5, variants, plan)
}

/// 4 phases × 5 steps under a zero-overhead always-steady policy. Each
/// phase records its first two steps, seals, and replays the remaining
/// three; each phase *entry* after the first invalidates. The counts
/// are exact, not bounds.
#[test]
fn seal_counts_match_the_phase_schedule_exactly() {
    let w = alternating(4, 5);
    let engine = Engine::new(EngineConfig { steps: 20, ..Default::default() });
    let mut m = Machine::new(MachineSpec::fast_only());
    let (r, d) = engine.run_dynamic(&w, &mut m, &mut StaticPolicy { tier: Tier::Fast }, true);
    assert_eq!(d.divergences, 3, "3 phase boundaries after step 0");
    assert_eq!(d.reprofiles, 3, "detector re-profiles at every divergence");
    assert_eq!(d.seals, 4, "each of the 4 phases seals once");
    assert_eq!(d.invalidations, 3, "each re-entry tears the old seal down");
    assert_eq!(d.stale_steps, 0, "the detector leaves no stale exposure");
    assert_eq!(r.sealed_steps, 4 * 3, "each 5-step phase replays 3 sealed steps");
    assert_eq!(r.steady_from_step, Some(2));
    assert!((d.thrash_ratio() - 0.75).abs() < 1e-12, "3 invalidations / 4 seals");
}

/// The same workload with the detector off: the phase-A seal survives
/// the whole run, is *never* replayed during phase B (that would be the
/// stale-replay bug this suite exists to catch), and resumes replaying
/// the moment the live phase matches it again.
#[test]
fn detector_off_replays_only_in_the_sealed_phase() {
    let w = alternating(4, 5);
    let engine = Engine::new(EngineConfig { steps: 20, ..Default::default() });
    let mut m = Machine::new(MachineSpec::fast_only());
    let (r, d) = engine.run_dynamic(&w, &mut m, &mut StaticPolicy { tier: Tier::Fast }, false);
    assert_eq!(d.divergences, 3);
    assert_eq!(d.reprofiles, 0, "no detector, no re-profiles");
    assert_eq!(d.seals, 1, "only phase A's first visit seals");
    assert_eq!(d.invalidations, 0);
    assert_eq!(d.stale_steps, 10, "both phase-B windows run under stale trust");
    // Steps 2-4 replay, 5-9 run live (phase B), 10-14 replay again
    // (back in the sealed phase), 15-19 run live.
    assert_eq!(r.sealed_steps, 3 + 5);
    // Phase-B steps carry 1.4× the non-persistent bytes: if the stale
    // phase-A seal had been replayed there, these times would collapse
    // onto the phase-A steady time.
    assert!(
        r.steps[7].time_ns > r.steps[3].time_ns,
        "phase-B live step ({}) must cost more than a phase-A sealed step ({})",
        r.steps[7].time_ns,
        r.steps[3].time_ns
    );
}

/// One Sentinel arm of the bit-compare: same dynamic workload, same
/// detector, sealing on or off.
fn sentinel_arm(w: &DynamicWorkload, steps: u32, seal: bool) -> (TrainResult, DivergenceStats) {
    let kind = PolicyKind::Sentinel(Default::default());
    let (bg, bt) = (&w.variants[0].graph, &w.variants[0].trace);
    let fast = RN32.peak_memory_target() / 5;
    let spec = kind.machine_spec(bg, bt, fast);
    let mut cfg = kind.engine_config(steps);
    cfg.seal_steady = seal;
    let mut policy = kind.construct(bg, bt, spec);
    let mut machine = Machine::new(spec);
    Engine::new(cfg).run_dynamic(w, &mut machine, policy.as_mut(), true)
}

/// The no-stale-replay proof: a detector-on run that seals, invalidates
/// and re-seals across phase changes is bit-identical to the pure-live
/// run (`seal_steady = false`) of the same workload. If any sealed
/// delta ever stood for a step of the wrong phase, the clocks would
/// drift and the bits would differ.
#[test]
fn detector_on_sealed_run_is_bit_identical_to_pure_live() {
    let steps = 48;
    let w = DynamicWorkload::build(RN32, DEFAULT_SEED, DynamicKind::VarBatch, 0.35, steps);
    assert!(w.n_switches() > 0, "the seed must actually produce phase switches");
    let (sealed, ds) = sentinel_arm(&w, steps, true);
    let (live, dl) = sentinel_arm(&w, steps, false);
    assert!(ds.seals > 0, "the sealed arm must exercise the seal machinery");
    assert_eq!(dl.seals, 0, "the live arm must not seal");
    assert_eq!(ds.divergences, dl.divergences, "detection is seal-independent");
    assert_eq!(ds.reprofiles, dl.reprofiles);
    assert_eq!(
        sealed.total_time_ns.to_bits(),
        live.total_time_ns.to_bits(),
        "sealed {} vs live {}",
        sealed.total_time_ns,
        live.total_time_ns
    );
    assert_eq!(sealed.steps.len(), live.steps.len());
    for (a, b) in sealed.steps.iter().zip(&live.steps) {
        assert_eq!(a.time_ns.to_bits(), b.time_ns.to_bits(), "step {}", a.step);
        assert_eq!(a.pages_in, b.pages_in, "step {}", a.step);
        assert_eq!(a.pages_out, b.pages_out, "step {}", a.step);
    }
    assert_eq!(
        sealed.pages_migrated_in, live.pages_migrated_in,
        "sealed deltas must re-apply the exact migration traffic"
    );
    assert_eq!(sealed.pages_migrated_out, live.pages_migrated_out);
}

/// The tentpole's headline: on the variable-batch workload at the
/// paper's 20% fast fraction, arming the detector strictly reduces
/// Sentinel's slowdown vs fast-only. Off, Sentinel keeps running the
/// stale step-1 plan (mis-sized short-lived reservations, blocked
/// re-sealing); on, it pays a small re-profile surcharge per divergence
/// and gets the placement right.
#[test]
fn detector_strictly_reduces_slowdown_vs_fast_only() {
    let steps = 40;
    let variability = 0.25;
    let spec = RunSpec::for_model(RN32)
        .steps(steps)
        .fast_pct(20)
        .seed(DEFAULT_SEED)
        .dynamic(DynamicKind::VarBatch, variability);
    let on = spec.clone().detector(true).run().unwrap();
    let off = spec.clone().detector(false).run().unwrap();
    let fast = RunSpec::for_model(RN32)
        .policy(PolicyKind::FastOnly)
        .steps(steps)
        .seed(DEFAULT_SEED)
        .dynamic(DynamicKind::VarBatch, variability)
        .run()
        .unwrap();

    let d_on = on.dynamics.as_ref().expect("variability > 0 reports dynamics");
    let d_off = off.dynamics.as_ref().expect("variability > 0 reports dynamics");
    assert!(d_on.divergences > 0, "the workload must actually diverge");
    assert_eq!(d_on.divergences, d_off.divergences, "same phase plan both arms");
    assert_eq!(d_on.reprofiles, d_on.divergences);
    assert_eq!(d_off.reprofiles, 0);
    assert!(d_off.stale_steps > 0, "detector-off must be exposed to stale trust");
    assert_eq!(d_on.stale_steps, 0);

    let fast_ns = fast.result.total_time_ns;
    assert!(fast_ns > 0.0);
    let slowdown_on = on.result.total_time_ns / fast_ns;
    let slowdown_off = off.result.total_time_ns / fast_ns;
    assert!(
        slowdown_on < slowdown_off,
        "detector on ({slowdown_on:.4}x) must beat detector off ({slowdown_off:.4}x)"
    );
    assert!(slowdown_on >= 1.0, "nothing beats fast-only: {slowdown_on:.4}");
}

/// Zero-variability equivalence: every dynamic kind at
/// `variability = 0.0`, detector armed, is JSON-bit-identical to its
/// static counterpart across the whole policy registry. JSON equality
/// is the repo's bit-identity proxy (floats print shortest-round-trip).
#[test]
fn zero_variability_is_json_identical_across_the_registry() {
    for kind in DynamicKind::all() {
        for policy in PolicyKind::all() {
            let fixed = RunSpec::for_model(Model::Dcgan)
                .policy(policy)
                .steps(10)
                .fast_pct(25)
                .seed(DEFAULT_SEED);
            let stat = fixed.clone().run().unwrap();
            let dynv = fixed.dynamic(kind, 0.0).detector(true).run().unwrap();
            assert_eq!(
                stat.to_json(),
                dynv.to_json(),
                "kind={} policy={}",
                kind.name(),
                policy.name()
            );
        }
    }
}
