//! The compiled-replay equivalence proof: [`Engine::run`] (CompiledTrace
//! fast path) must produce **bit-identical** `TrainResult`s to
//! [`Engine::run_legacy`] (the pre-compilation event-by-event loop) for
//! every policy in the registry.
//!
//! Two parts:
//! * an exhaustive grid over `PolicyKind::all()` × {DCGAN, ResNet_v1-32}
//!   × fast-pct {15, 20, 35} (the ISSUE-2 acceptance matrix), and
//! * a property test (via `util::prop`) over random fast sizes, step
//!   counts, seeds and policies.

use sentinel_hm::api::PolicyKind;
use sentinel_hm::dnn::zoo::Model;
use sentinel_hm::dnn::StepTrace;
use sentinel_hm::sim::{Engine, Machine, TrainResult};
use sentinel_hm::util::prop::check;

const MODELS: [Model; 2] = [Model::Dcgan, Model::ResNetV1 { depth: 32 }];

/// Exact (bit-level for floats) equality of two results.
fn assert_bit_identical(a: &TrainResult, b: &TrainResult, ctx: &str) {
    assert_eq!(a.policy, b.policy, "{ctx}: policy");
    assert_eq!(a.model, b.model, "{ctx}: model");
    assert_eq!(
        a.total_time_ns.to_bits(),
        b.total_time_ns.to_bits(),
        "{ctx}: total_time_ns {} vs {}",
        a.total_time_ns,
        b.total_time_ns
    );
    assert_eq!(a.peak_fast_bytes, b.peak_fast_bytes, "{ctx}: peak_fast_bytes");
    assert_eq!(a.peak_total_bytes, b.peak_total_bytes, "{ctx}: peak_total_bytes");
    assert_eq!(a.pages_migrated_in, b.pages_migrated_in, "{ctx}: pages_in");
    assert_eq!(a.pages_migrated_out, b.pages_migrated_out, "{ctx}: pages_out");
    assert_eq!(a.alloc_spills, b.alloc_spills, "{ctx}: alloc_spills");
    assert_eq!(a.steps.len(), b.steps.len(), "{ctx}: step count");
    for (sa, sb) in a.steps.iter().zip(&b.steps) {
        assert_eq!(sa.step, sb.step, "{ctx}: step index");
        assert_eq!(
            sa.time_ns.to_bits(),
            sb.time_ns.to_bits(),
            "{ctx}: step {} time {} vs {}",
            sa.step,
            sa.time_ns,
            sb.time_ns
        );
        assert_eq!(sa.pages_in, sb.pages_in, "{ctx}: step {} pages_in", sa.step);
        assert_eq!(sa.pages_out, sb.pages_out, "{ctx}: step {} pages_out", sa.step);
    }
}

/// Run one configuration through both replay paths on fresh, identical
/// machines/policies and compare.
fn check_equivalence(
    g: &sentinel_hm::dnn::ModelGraph,
    trace: &StepTrace,
    kind: PolicyKind,
    fast_bytes: u64,
    steps: u32,
    ctx: &str,
) {
    let spec = kind.machine_spec(g, trace, fast_bytes);
    let engine = Engine::new(kind.engine_config(steps));

    let mut m_new = Machine::new(spec);
    let mut p_new = kind.construct(g, trace, spec);
    let compiled = engine.run(g, trace, &mut m_new, p_new.as_mut());

    let mut m_old = Machine::new(spec);
    let mut p_old = kind.construct(g, trace, spec);
    let legacy = engine.run_legacy(g, trace, &mut m_old, p_old.as_mut());

    assert_bit_identical(&compiled, &legacy, ctx);
}

#[test]
fn compiled_replay_is_bit_identical_across_registry_grid() {
    for model in MODELS {
        let g = model.build(1);
        let trace = StepTrace::from_graph(&g);
        let peak = model.peak_memory_target();
        for kind in PolicyKind::all() {
            for pct in [15u64, 20, 35] {
                let fast = peak * pct / 100;
                let ctx = format!("{} / {} / fast={pct}%", model.name(), kind.name());
                check_equivalence(&g, &trace, kind, fast, 8, &ctx);
            }
        }
    }
}

#[test]
fn compiled_replay_equivalence_property() {
    // Random fast sizes (including degenerate slivers), step counts and
    // seeds. DCGAN only: the property runs many cases.
    let g_cache: Vec<(u64, sentinel_hm::dnn::ModelGraph, StepTrace)> = [2u64, 9]
        .iter()
        .map(|&seed| {
            let g = Model::Dcgan.build(seed);
            let t = StepTrace::from_graph(&g);
            (seed, g, t)
        })
        .collect();
    let peak = Model::Dcgan.peak_memory_target();
    check("compiled replay ≡ legacy replay", 24, |tc| {
        let (_, g, trace) = &g_cache[tc.range(0, 1) as usize];
        let kinds = PolicyKind::all();
        let kind = kinds[tc.range(0, (kinds.len() - 1) as u64) as usize];
        // 5%..=60% of reported peak, and 2..=10 steps.
        let pct = tc.range(5, 60);
        let steps = tc.range(2, 10) as u32;
        let fast = (peak * pct / 100).max(1);
        let ctx = format!("prop: {} fast={pct}% steps={steps}", kind.name());
        check_equivalence(g, trace, kind, fast, steps, &ctx);
    });
}
