//! Checkpoint/restore equivalence: a run killed at a step boundary and
//! resumed from its checkpoint must reproduce the uninterrupted run
//! **bit for bit** (JSON-identical outcomes, float bits included).
//!
//! * **Kill-at-every-boundary**: for every policy in
//!   [`PolicyKind::all`], a solo run checkpointed at every step is
//!   resumed from *each* written checkpoint and compared to the
//!   uninterrupted baseline. Same for a 3-tenant cluster, a faulted
//!   fleet with crashes, and a dynamic workload with the divergence
//!   detector armed (PR 7 fault state and PR 8 detector state must
//!   round-trip too).
//! * **Observational**: writing checkpoints must not perturb the run —
//!   the checkpointing run's own outcome equals the plain run's.
//! * **Resume-twice determinism**: resuming the same file twice gives
//!   identical output.
//! * **Typed rejection**: wrong-kind, wrong-spec, truncated, bit-flipped
//!   and missing checkpoint files surface as [`CheckpointError`]
//!   variants through the spec layer — never a panic.
//! * **Property**: random (steps, interval, resume-point) triples drawn
//!   from a seeded LCG all satisfy resume ≡ uninterrupted.

use std::fs;
use std::path::{Path, PathBuf};

use sentinel_hm::api::{
    Admission, Autoscale, ClusterSpec, FaultSpec, FleetSpec, PolicyKind, RunSpec, SimError,
    TenantSpec,
};
use sentinel_hm::dnn::zoo::Model;
use sentinel_hm::dnn::DynamicKind;
use sentinel_hm::sim::{load_checkpoint, CheckpointError};

/// Fresh per-test scratch directory under the system temp dir.
fn tdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("sentinel-ckpt-resume-{}-{}", tag, std::process::id()));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(&d).unwrap();
    d
}

/// All checkpoint files in `dir`, sorted by progress (the zero-padded
/// file name sorts correctly).
fn ckpts(dir: &Path) -> Vec<PathBuf> {
    let mut v: Vec<PathBuf> = fs::read_dir(dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().map_or(false, |x| x == "ckpt"))
        .collect();
    v.sort();
    v
}

/// For every policy: checkpoint a short solo run at every step boundary,
/// then resume from each file; all outcomes must equal the plain run.
#[test]
fn solo_kill_at_every_boundary_matches_uninterrupted_for_all_policies() {
    for kind in PolicyKind::all() {
        let dir = tdir(&format!("solo-{kind:?}").replace(['(', ')', ' ', '{', '}', ':'], "-"));
        let spec = || RunSpec::for_model(Model::Dcgan).policy(kind).fast_pct(30).steps(6);
        let base = spec().run().unwrap().to_json();
        let ckpt_run = spec()
            .checkpoint_every(1)
            .checkpoint_dir(&dir)
            .run_checkpointed()
            .unwrap()
            .to_json();
        assert_eq!(base, ckpt_run, "{kind:?}: writing checkpoints perturbed the run");
        let files = ckpts(&dir);
        assert_eq!(files.len(), 6, "{kind:?}: one checkpoint per step boundary");
        for f in &files {
            let resumed = spec().resume_from(f).run_checkpointed().unwrap().to_json();
            assert_eq!(base, resumed, "{kind:?}: resume from {} diverged", f.display());
        }
        let _ = fs::remove_dir_all(&dir);
    }
}

fn cluster_spec() -> ClusterSpec {
    let fast = Model::Dcgan.peak_memory_target() * 3 / 10;
    ClusterSpec::new()
        .tenant(TenantSpec::for_model(Model::Dcgan).policy(PolicyKind::Lru))
        .tenant(TenantSpec::for_model(Model::Dcgan).policy(PolicyKind::StaticInterval(4)))
        .tenant(TenantSpec::for_model(Model::Dcgan).policy(PolicyKind::Ial))
        .fast_bytes(fast)
        .steps(6)
}

/// A 3-tenant cluster checkpointed at every tenant-step boundary,
/// resumed from each file.
#[test]
fn cluster_kill_at_every_boundary_matches_uninterrupted() {
    let dir = tdir("cluster");
    let base = cluster_spec().run().unwrap().to_json();
    let ckpt_run = cluster_spec()
        .checkpoint_every(1)
        .checkpoint_dir(&dir)
        .run_checkpointed()
        .unwrap()
        .to_json();
    assert_eq!(base, ckpt_run, "writing checkpoints perturbed the cluster run");
    let files = ckpts(&dir);
    assert!(!files.is_empty(), "cluster run wrote no checkpoints");
    for f in &files {
        let resumed = cluster_spec().resume_from(f).run_checkpointed().unwrap().to_json();
        assert_eq!(base, resumed, "cluster resume from {} diverged", f.display());
    }
    let _ = fs::remove_dir_all(&dir);
}

fn faulted_fleet() -> FleetSpec {
    FleetSpec::new()
        .tenants(8)
        .rate_per_s(2.0)
        .machines(2)
        .machine_fast_bytes(3 << 30)
        .admission(Admission::Queue)
        .autoscale(Autoscale::default())
        .threads(1)
        .seed(17)
        .faults(FaultSpec::new().rate(0.15).crashes(true))
}

/// A faulted fleet (crashes enabled) checkpointed every other event
/// round: resuming from each checkpoint — including rounds after
/// machines have crashed — reproduces the uninterrupted outcome,
/// fault plan positions and all.
#[test]
fn faulted_fleet_kill_at_every_checkpoint_matches_uninterrupted() {
    let dir = tdir("fleet");
    let baseline = faulted_fleet().run().unwrap();
    let base = baseline.to_json();
    let report = baseline.faults.as_ref().expect("plan armed");
    assert!(report.injected > 0, "rate 0.15 over this run must inject something");
    let ckpt_run = faulted_fleet()
        .checkpoint_every(2)
        .checkpoint_dir(&dir)
        .run_checkpointed()
        .unwrap();
    assert_eq!(base, ckpt_run.to_json(), "writing checkpoints perturbed the fleet run");
    let files = ckpts(&dir);
    assert!(!files.is_empty(), "fleet run wrote no checkpoints");
    for f in &files {
        let resumed = faulted_fleet().resume_from(f).run_checkpointed().unwrap();
        assert_eq!(base, resumed.to_json(), "fleet resume from {} diverged", f.display());
        assert_eq!(baseline.tenants_digest(), resumed.tenants_digest());
    }
    let _ = fs::remove_dir_all(&dir);
}

fn dynamic_spec() -> RunSpec {
    RunSpec::for_model(Model::Dcgan)
        .dynamic(DynamicKind::Moe, 0.6)
        .detector(true)
        .fast_pct(30)
        .steps(8)
}

/// A dynamic (MoE) run with the online divergence detector armed:
/// detector counters and the dynamic RNG substream must round-trip
/// through every checkpoint.
#[test]
fn dynamic_detector_run_kill_at_every_boundary_matches_uninterrupted() {
    let dir = tdir("dynamic");
    let base = dynamic_spec().run().unwrap().to_json();
    let ckpt_run = dynamic_spec()
        .checkpoint_every(1)
        .checkpoint_dir(&dir)
        .run_checkpointed()
        .unwrap()
        .to_json();
    assert_eq!(base, ckpt_run, "writing checkpoints perturbed the dynamic run");
    let files = ckpts(&dir);
    assert_eq!(files.len(), 8, "one checkpoint per dynamic step boundary");
    for f in &files {
        let resumed = dynamic_spec().resume_from(f).run_checkpointed().unwrap().to_json();
        assert_eq!(base, resumed, "dynamic resume from {} diverged", f.display());
    }
    let _ = fs::remove_dir_all(&dir);
}

/// Resuming the same checkpoint twice is itself deterministic.
#[test]
fn resume_twice_is_deterministic() {
    let dir = tdir("twice");
    let spec = || RunSpec::for_model(Model::Dcgan).policy(PolicyKind::Lru).fast_pct(30).steps(8);
    spec().checkpoint_every(4).checkpoint_dir(&dir).run_checkpointed().unwrap();
    let mid = ckpts(&dir).into_iter().next().expect("a mid-run checkpoint");
    let a = spec().resume_from(&mid).run_checkpointed().unwrap().to_json();
    let b = spec().resume_from(&mid).run_checkpointed().unwrap().to_json();
    assert_eq!(a, b, "two resumes from {} disagree", mid.display());
    let _ = fs::remove_dir_all(&dir);
}

/// Corrupt or mismatched checkpoints surface as typed errors through
/// the spec layer, one class at a time — never a panic.
#[test]
fn spec_layer_rejects_mismatched_and_corrupt_checkpoints_with_typed_errors() {
    let dir = tdir("reject");
    let spec = || RunSpec::for_model(Model::Dcgan).policy(PolicyKind::Lru).fast_pct(30).steps(4);
    spec().checkpoint_every(2).checkpoint_dir(&dir).run_checkpointed().unwrap();
    let solo = ckpts(&dir).into_iter().next().expect("a solo checkpoint");
    load_checkpoint(&solo).expect("the file itself is well-formed");

    // Wrong kind: a fleet spec refusing a solo checkpoint.
    let err = FleetSpec::new().resume_from(&solo).run_checkpointed().unwrap_err();
    assert!(
        matches!(err, SimError::Checkpoint(CheckpointError::KindMismatch { .. })),
        "fleet resume of a solo checkpoint: {err:?}"
    );

    // Wrong spec: same shape, different seed → fingerprint mismatch.
    let err = spec().seed(99).resume_from(&solo).run_checkpointed().unwrap_err();
    assert!(
        matches!(err, SimError::Checkpoint(CheckpointError::SpecMismatch { .. })),
        "different-seed resume: {err:?}"
    );

    // Truncated file.
    let bytes = fs::read(&solo).unwrap();
    let cut = dir.join("cut.ckpt");
    fs::write(&cut, &bytes[..20]).unwrap();
    let err = spec().resume_from(&cut).run_checkpointed().unwrap_err();
    assert!(
        matches!(err, SimError::Checkpoint(CheckpointError::Truncated)),
        "truncated resume: {err:?}"
    );

    // Bit-flipped payload byte.
    let mut flipped = bytes.clone();
    let mid = flipped.len() - 10;
    flipped[mid] ^= 0x40;
    let flip = dir.join("flip.ckpt");
    fs::write(&flip, &flipped).unwrap();
    let err = spec().resume_from(&flip).run_checkpointed().unwrap_err();
    assert!(
        matches!(err, SimError::Checkpoint(CheckpointError::BadChecksum { .. })),
        "bit-flipped resume: {err:?}"
    );

    // Missing file.
    let err = spec().resume_from(dir.join("nope.ckpt")).run_checkpointed().unwrap_err();
    assert!(matches!(err, SimError::Checkpoint(CheckpointError::Io(_))), "missing file: {err:?}");
    let _ = fs::remove_dir_all(&dir);
}

/// Tiny deterministic LCG so the property trial set is stable run to
/// run (no wall-clock or OS randomness in tests).
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0 >> 33
    }
}

/// Property: for random (steps, checkpoint interval, resume point)
/// triples, resume ≡ uninterrupted.
#[test]
fn property_random_checkpoint_points_all_resume_identically() {
    let mut rng = Lcg(0x5EED_CAFE);
    let policies = [PolicyKind::Lru, PolicyKind::Ial, PolicyKind::StaticInterval(3)];
    for trial in 0..4 {
        let steps = 4 + (rng.next() % 6) as u32; // 4..=9
        let every = 1 + rng.next() % 3; // 1..=3
        let kind = policies[(rng.next() % policies.len() as u64) as usize];
        let seed = rng.next();
        let dir = tdir(&format!("prop-{trial}"));
        let spec = || {
            RunSpec::for_model(Model::Dcgan).policy(kind).fast_pct(30).steps(steps).seed(seed)
        };
        let base = spec().run().unwrap().to_json();
        spec().checkpoint_every(every).checkpoint_dir(&dir).run_checkpointed().unwrap();
        let files = ckpts(&dir);
        assert!(!files.is_empty(), "trial {trial}: steps={steps} every={every} wrote nothing");
        let pick = &files[(rng.next() % files.len() as u64) as usize];
        let resumed = spec().resume_from(pick).run_checkpointed().unwrap().to_json();
        assert_eq!(
            base,
            resumed,
            "trial {trial}: steps={steps} every={every} resume from {} diverged",
            pick.display()
        );
        let _ = fs::remove_dir_all(&dir);
    }
}
