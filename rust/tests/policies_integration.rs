//! Cross-policy integration tests over the full simulator stack: every
//! model in the zoo, every policy, checking the orderings the paper's
//! evaluation establishes. All runs go through the `api` front door.

use sentinel_hm::api::{PolicyKind, RunOutcome, RunSpec};
use sentinel_hm::coordinator::sentinel::SentinelConfig;
use sentinel_hm::dnn::zoo::Model;

const STEPS: u32 = 14;

fn run(model: Model, policy: PolicyKind, steps: u32) -> RunOutcome {
    RunSpec::for_model(model)
        .fast_pct(20)
        .policy(policy)
        .steps(steps)
        .run()
        .expect("run")
}

#[test]
fn all_models_policy_ordering_at_20pct() {
    for model in Model::paper_five() {
        let fthr = run(model, PolicyKind::FastOnly, 5).throughput();
        let sthr = run(model, PolicyKind::Sentinel(Default::default()), STEPS).throughput();
        let ithr = run(model, PolicyKind::Ial, STEPS).throughput();
        let slow = run(model, PolicyKind::SlowOnly, 3).throughput();
        let name = model.name();
        // Paper Fig. 10 orderings.
        assert!(sthr <= fthr * 1.02, "{name}: Sentinel can't beat fast-only");
        assert!(
            sthr >= 0.85 * fthr,
            "{name}: Sentinel must be within 15% of fast-only ({:.3})",
            sthr / fthr
        );
        assert!(sthr > ithr, "{name}: Sentinel must beat IAL");
        assert!(ithr > slow * 0.99, "{name}: IAL must beat slow-only");
        assert!(slow < 0.95 * fthr, "{name}: slow-only must trail fast-only");
    }
}

#[test]
fn sentinel_beats_ial_by_meaningful_margin() {
    // Paper: +18% on average. Require ≥ +5% on average across models.
    let mut ratios = Vec::new();
    for model in Model::paper_five() {
        let s = run(model, PolicyKind::Sentinel(Default::default()), STEPS);
        let i = run(model, PolicyKind::Ial, STEPS);
        ratios.push(s.throughput() / i.throughput());
    }
    let avg = ratios.iter().sum::<f64>() / ratios.len() as f64;
    assert!(avg > 1.05, "Sentinel/IAL avg {avg:.3} (paper: 1.18)");
}

#[test]
fn sentinel_migrates_more_than_ial() {
    // Paper Table 4: Sentinel has ~88% more migrations — frequent,
    // well-overlapped migration is the design, not a bug.
    let mut more = 0;
    for model in Model::paper_five() {
        let s = run(model, PolicyKind::Sentinel(Default::default()), STEPS);
        let i = run(model, PolicyKind::Ial, STEPS);
        if s.result.total_migrations() > i.result.total_migrations() {
            more += 1;
        }
    }
    assert!(more >= 3, "Sentinel should out-migrate IAL on most models ({more}/5)");
}

#[test]
fn lru_is_between_slow_and_fast() {
    let model = Model::ResNetV1 { depth: 32 };
    let fthr = run(model, PolicyKind::FastOnly, 5).throughput();
    let lthr = run(model, PolicyKind::Lru, STEPS).throughput();
    let slow = run(model, PolicyKind::SlowOnly, 3).throughput();
    assert!(lthr < fthr * 1.01);
    assert!(lthr > slow);
}

#[test]
fn fig12_larger_fast_memory_never_hurts_much() {
    for model in [Model::ResNetV1 { depth: 32 }, Model::Dcgan] {
        let mut prev = 0.0;
        for pct in [10u32, 20, 40, 60] {
            let thr = RunSpec::for_model(model)
                .fast_pct(pct)
                .steps(STEPS)
                .run()
                .expect("run")
                .throughput();
            assert!(
                thr >= prev * 0.97,
                "{}: throughput dropped {prev:.3} → {thr:.3} at {pct}%",
                model.name()
            );
            prev = thr;
        }
    }
}

#[test]
fn fig13_required_fast_share_does_not_grow_with_depth() {
    let rows = sentinel_hm::figures::fig13_variants(10);
    assert_eq!(rows.len(), 5);
    let first = rows[0].2 as f64 / rows[0].1 as f64;
    let last = rows.last().unwrap().2 as f64 / rows.last().unwrap().1 as f64;
    assert!(last <= first + 0.05, "fast share grew: {first:.2} → {last:.2}");
    // Peaks grow with depth.
    for w in rows.windows(2) {
        assert!(w[1].1 > w[0].1);
    }
}

#[test]
fn ablations_cost_performance() {
    let model = Model::ResNetV1 { depth: 32 };
    let base = run(model, PolicyKind::Sentinel(Default::default()), STEPS).throughput();
    let no_rs = run(
        model,
        PolicyKind::Sentinel(SentinelConfig { reserve_space: false, ..Default::default() }),
        STEPS,
    );
    let no_fs = run(
        model,
        PolicyKind::Sentinel(SentinelConfig {
            handle_false_sharing: false,
            ..Default::default()
        }),
        STEPS,
    );
    assert!(no_rs.throughput() <= base * 1.02);
    assert!(no_fs.throughput() <= base * 1.02);
}

#[test]
fn tuning_steps_are_bounded_like_table3() {
    // Paper Table 3: 2–8 steps for profiling + MI search + trial.
    for model in Model::paper_five() {
        let out = run(model, PolicyKind::Sentinel(Default::default()), STEPS);
        assert!(
            (2..=10).contains(&out.warmup_steps),
            "{}: tuning steps {} out of Table-3 range",
            model.name(),
            out.warmup_steps
        );
    }
}
