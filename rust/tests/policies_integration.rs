//! Cross-policy integration tests over the full simulator stack: every
//! model in the zoo, every policy, checking the orderings the paper's
//! evaluation establishes.

use sentinel_hm::coordinator::sentinel::{run_fast_only, run_sentinel, SentinelConfig};
use sentinel_hm::dnn::zoo::Model;
use sentinel_hm::dnn::StepTrace;
use sentinel_hm::figures::{run_ial, run_lru};
use sentinel_hm::sim::{Engine, EngineConfig, Machine, MachineSpec, Tier};

const STEPS: u32 = 14;

fn slow_only(g: &sentinel_hm::dnn::ModelGraph) -> f64 {
    let trace = StepTrace::from_graph(g);
    let mut m = Machine::new(MachineSpec::slow_only());
    let e = Engine::new(EngineConfig { steps: 3, ..Default::default() });
    e.run(
        g,
        &trace,
        &mut m,
        &mut sentinel_hm::sim::engine::StaticPolicy { tier: Tier::Slow },
    )
    .throughput(1)
}

#[test]
fn all_models_policy_ordering_at_20pct() {
    for model in Model::paper_five() {
        let g = model.build(0x5E17);
        let fast = model.peak_memory_target() / 5;
        let fthr = run_fast_only(&g, 5).throughput(1);
        let (s, _, tuning) = run_sentinel(&g, fast, STEPS, SentinelConfig::default());
        let sthr = s.throughput(tuning as usize);
        let ithr = run_ial(&g, fast, STEPS).throughput(3);
        let slow = slow_only(&g);
        let name = model.name();
        // Paper Fig. 10 orderings.
        assert!(sthr <= fthr * 1.02, "{name}: Sentinel can't beat fast-only");
        assert!(
            sthr >= 0.85 * fthr,
            "{name}: Sentinel must be within 15% of fast-only ({:.3})",
            sthr / fthr
        );
        assert!(sthr > ithr, "{name}: Sentinel must beat IAL");
        assert!(ithr > slow * 0.99, "{name}: IAL must beat slow-only");
        assert!(slow < 0.95 * fthr, "{name}: slow-only must trail fast-only");
    }
}

#[test]
fn sentinel_beats_ial_by_meaningful_margin() {
    // Paper: +18% on average. Require ≥ +5% on average across models.
    let mut ratios = Vec::new();
    for model in Model::paper_five() {
        let g = model.build(0x5E17);
        let fast = model.peak_memory_target() / 5;
        let (s, _, t) = run_sentinel(&g, fast, STEPS, SentinelConfig::default());
        let i = run_ial(&g, fast, STEPS);
        ratios.push(s.throughput(t as usize) / i.throughput(3));
    }
    let avg = ratios.iter().sum::<f64>() / ratios.len() as f64;
    assert!(avg > 1.05, "Sentinel/IAL avg {avg:.3} (paper: 1.18)");
}

#[test]
fn sentinel_migrates_more_than_ial() {
    // Paper Table 4: Sentinel has ~88% more migrations — frequent,
    // well-overlapped migration is the design, not a bug.
    let mut more = 0;
    for model in Model::paper_five() {
        let g = model.build(0x5E17);
        let fast = model.peak_memory_target() / 5;
        let (s, _, _) = run_sentinel(&g, fast, STEPS, SentinelConfig::default());
        let i = run_ial(&g, fast, STEPS);
        if s.total_migrations() > i.total_migrations() {
            more += 1;
        }
    }
    assert!(more >= 3, "Sentinel should out-migrate IAL on most models ({more}/5)");
}

#[test]
fn lru_is_between_slow_and_fast() {
    let model = Model::ResNetV1 { depth: 32 };
    let g = model.build(0x5E17);
    let fast = model.peak_memory_target() / 5;
    let fthr = run_fast_only(&g, 5).throughput(1);
    let lthr = run_lru(&g, fast, STEPS).throughput(3);
    let slow = slow_only(&g);
    assert!(lthr < fthr * 1.01);
    assert!(lthr > slow);
}

#[test]
fn fig12_larger_fast_memory_never_hurts_much() {
    for model in [Model::ResNetV1 { depth: 32 }, Model::Dcgan] {
        let g = model.build(0x5E17);
        let mut prev = 0.0;
        for pct in [10u64, 20, 40, 60] {
            let fast = model.peak_memory_target() * pct / 100;
            let (r, _, t) = run_sentinel(&g, fast, STEPS, SentinelConfig::default());
            let thr = r.throughput(t as usize);
            assert!(
                thr >= prev * 0.97,
                "{}: throughput dropped {prev:.3} → {thr:.3} at {pct}%",
                model.name()
            );
            prev = thr;
        }
    }
}

#[test]
fn fig13_required_fast_share_does_not_grow_with_depth() {
    let rows = sentinel_hm::figures::fig13_variants(10);
    assert_eq!(rows.len(), 5);
    let first = rows[0].2 as f64 / rows[0].1 as f64;
    let last = rows.last().unwrap().2 as f64 / rows.last().unwrap().1 as f64;
    assert!(last <= first + 0.05, "fast share grew: {first:.2} → {last:.2}");
    // Peaks grow with depth.
    for w in rows.windows(2) {
        assert!(w[1].1 > w[0].1);
    }
}

#[test]
fn ablations_cost_performance() {
    let model = Model::ResNetV1 { depth: 32 };
    let g = model.build(0x5E17);
    let fast = model.peak_memory_target() / 5;
    let (full, _, t) = run_sentinel(&g, fast, STEPS, SentinelConfig::default());
    let base = full.throughput(t as usize);
    let (no_rs, _, t2) = run_sentinel(
        &g,
        fast,
        STEPS,
        SentinelConfig { reserve_space: false, ..Default::default() },
    );
    let (no_fs, _, t3) = run_sentinel(
        &g,
        fast,
        STEPS,
        SentinelConfig { handle_false_sharing: false, ..Default::default() },
    );
    assert!(no_rs.throughput(t2 as usize) <= base * 1.02);
    assert!(no_fs.throughput(t3 as usize) <= base * 1.02);
}

#[test]
fn tuning_steps_are_bounded_like_table3() {
    // Paper Table 3: 2–8 steps for profiling + MI search + trial.
    for model in Model::paper_five() {
        let g = model.build(0x5E17);
        let fast = model.peak_memory_target() / 5;
        let (_, _, tuning) = run_sentinel(&g, fast, STEPS, SentinelConfig::default());
        assert!(
            (2..=10).contains(&tuning),
            "{}: tuning steps {tuning} out of Table-3 range",
            model.name()
        );
    }
}
