//! Quickstart: profile a model, let Sentinel tune itself, and compare
//! against the fast-memory-only reference — the paper's headline claim
//! in ~30 lines of user code.
//!
//! Run: `cargo run --release --example quickstart`

use sentinel_hm::coordinator::sentinel::{run_fast_only, run_sentinel, SentinelConfig};
use sentinel_hm::dnn::zoo::Model;
use sentinel_hm::dnn::StepTrace;
use sentinel_hm::profiler::profile;
use sentinel_hm::util::table::fmt_bytes;

fn main() {
    // 1. Pick a model from the zoo (the paper's Table 3).
    let model = Model::ResNetV1 { depth: 32 };
    let graph = model.build(0x5E17);
    let trace = StepTrace::from_graph(&graph);
    println!(
        "{}: {} layers, {} data objects, {} live peak",
        graph.name,
        graph.n_layers(),
        graph.objects.len(),
        fmt_bytes(graph.peak_live_bytes()),
    );

    // 2. One-step object-granularity profile (§3).
    let report = profile(&graph, &trace);
    println!(
        "profile: {:.1}% of objects are short-lived; {:.1}% of those are < 4KB",
        report.short_lived_fraction() * 100.0,
        report.short_lived_small_fraction() * 100.0,
    );

    // 3. Train with only 20% of the reported peak as fast memory.
    let fast = model.peak_memory_target() / 5;
    println!("\ntraining with fast memory = {} (20% of peak)…", fmt_bytes(fast));
    let (result, cases, tuning) = run_sentinel(&graph, fast, 14, SentinelConfig::default());
    let reference = run_fast_only(&graph, 6);

    // 4. The headline: Sentinel ≈ fast-memory-only.
    let ratio = result.throughput(tuning as usize) / reference.throughput(1);
    println!(
        "sentinel:  {:.3} steps/s (tuned in {} steps; cases 1/2/3 = {}/{}/{})",
        result.throughput(tuning as usize),
        tuning,
        cases.case1,
        cases.case2,
        cases.case3,
    );
    println!("fast-only: {:.3} steps/s", reference.throughput(1));
    println!(
        "→ {:.1}% of fast-memory-only performance with 80% less fast memory \
         ({} pages migrated)",
        ratio * 100.0,
        result.total_migrations(),
    );
    assert!(ratio > 0.85, "quickstart regression: ratio {ratio}");
}
