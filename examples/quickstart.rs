//! Quickstart: profile a model, let Sentinel tune itself, and compare
//! against the fast-memory-only reference — the paper's headline claim
//! in ~30 lines of user code, all through the `api` front door.
//!
//! Run: `cargo run --release --example quickstart`

use sentinel_hm::api::{PolicyKind, RunSpec};
use sentinel_hm::dnn::zoo::Model;
use sentinel_hm::util::table::fmt_bytes;

fn main() {
    // 1. Pick a model from the zoo (the paper's Table 3) and train with
    //    only 20% of the reported peak as fast memory.
    let model = Model::ResNetV1 { depth: 32 };
    println!(
        "training {} with fast memory = {} (20% of reported peak)…",
        model.name(),
        fmt_bytes(model.peak_memory_target() / 5),
    );
    let result = RunSpec::for_model(model)
        .fast_fraction(0.2)
        .steps(14)
        .run()
        .expect("sentinel run");

    // 2. The one-step object-granularity profile (§3) rode along.
    let profile = result.profile.expect("sentinel profiles on step 0");
    println!(
        "profile: {} objects; {:.1}% short-lived; {:.1}% of those < 4KB",
        profile.n_objects,
        profile.short_lived_fraction * 100.0,
        profile.short_lived_small_fraction * 100.0,
    );

    // 3. The fast-memory-only reference the paper normalizes against.
    let reference = RunSpec::for_model(model)
        .policy(PolicyKind::FastOnly)
        .steps(6)
        .run()
        .expect("fast-only run");

    // 4. The headline: Sentinel ≈ fast-memory-only.
    let cases = result.cases.expect("sentinel classifies intervals");
    let ratio = result.throughput() / reference.throughput();
    println!(
        "sentinel:  {:.3} steps/s (tuned in {} steps; MI={}; cases 1/2/3 = {}/{}/{})",
        result.throughput(),
        result.warmup_steps,
        result.chosen_mi.unwrap_or(0),
        cases.case1,
        cases.case2,
        cases.case3,
    );
    println!("fast-only: {:.3} steps/s", reference.throughput());
    println!(
        "→ {:.1}% of fast-memory-only performance with 80% less fast memory \
         ({} pages migrated)",
        ratio * 100.0,
        result.result.total_migrations(),
    );
    assert!(ratio > 0.85, "quickstart regression: ratio {ratio}");
}
