//! Policy shoot-out across the paper's five models: fast-only (upper
//! bound), Sentinel, IAL (Yan et al.), LRU caching, and slow-only
//! (lower bound), all at fast = 20% of reported peak memory.
//!
//! Run: `cargo run --release --example compare_policies`

use sentinel_hm::coordinator::sentinel::{run_fast_only, run_sentinel, SentinelConfig};
use sentinel_hm::dnn::zoo::Model;
use sentinel_hm::dnn::StepTrace;
use sentinel_hm::figures::{run_ial, run_lru};
use sentinel_hm::sim::{Engine, EngineConfig, Machine, MachineSpec, Tier};
use sentinel_hm::util::table::Table;

fn main() {
    let steps = 14;
    let mut table = Table::new(vec![
        "model", "fast-only", "Sentinel", "IAL", "LRU", "slow-only",
    ]);
    let mut sentinel_vs_ial = Vec::new();

    for model in Model::paper_five() {
        let g = model.build(0x5E17);
        let trace = StepTrace::from_graph(&g);
        let fast = model.peak_memory_target() / 5;

        let reference = run_fast_only(&g, 6);
        let fthr = reference.throughput(1);

        let (s, _, tuning) = run_sentinel(&g, fast, steps, SentinelConfig::default());
        let ial = run_ial(&g, fast, steps);
        let lru = run_lru(&g, fast, steps);

        let mut slow_machine = Machine::new(MachineSpec::slow_only());
        let engine = Engine::new(EngineConfig { steps: 4, ..Default::default() });
        let slow = engine.run(
            &g,
            &trace,
            &mut slow_machine,
            &mut sentinel_hm::sim::engine::StaticPolicy { tier: Tier::Slow },
        );

        let s_norm = s.throughput(tuning as usize) / fthr;
        let ial_norm = ial.throughput(3) / fthr;
        sentinel_vs_ial.push(s_norm / ial_norm);
        table.row(vec![
            model.name(),
            "1.000".to_string(),
            format!("{:.3}", s_norm),
            format!("{:.3}", ial_norm),
            format!("{:.3}", lru.throughput(3) / fthr),
            format!("{:.3}", slow.throughput(1) / fthr),
        ]);
    }

    println!("normalized training throughput (fast = 20% of peak):\n");
    table.print();
    let avg: f64 = sentinel_vs_ial.iter().sum::<f64>() / sentinel_vs_ial.len() as f64;
    println!(
        "\nSentinel outperforms IAL by {:.1}% on average (paper: 18%)",
        (avg - 1.0) * 100.0
    );
}
