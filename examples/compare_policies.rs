//! Policy shoot-out across the paper's five models: fast-only (upper
//! bound), Sentinel, IAL (Yan et al.), LRU caching, and slow-only
//! (lower bound), all at fast = 20% of reported peak memory.
//!
//! The whole (model × policy) grid is a `Vec<RunSpec>` fanned across
//! every core by `run_batch` — the serial per-model loop of the old API
//! is gone.
//!
//! Run: `cargo run --release --example compare_policies`

use sentinel_hm::api::{default_threads, run_batch, PolicyKind, RunSpec};
use sentinel_hm::dnn::zoo::Model;
use sentinel_hm::util::table::Table;

fn main() {
    let steps = 14;
    let models = Model::paper_five();
    // Per model: reference, Sentinel, IAL, LRU, slow-only.
    let policies = [
        (PolicyKind::FastOnly, 6u32),
        (PolicyKind::Sentinel(Default::default()), steps),
        (PolicyKind::Ial, steps),
        (PolicyKind::Lru, steps),
        (PolicyKind::SlowOnly, 4),
    ];
    let specs: Vec<RunSpec> = models
        .iter()
        .flat_map(|&m| {
            policies
                .iter()
                .map(move |&(p, s)| RunSpec::for_model(m).fast_pct(20).policy(p).steps(s))
        })
        .collect();
    let outs = run_batch(specs, default_threads());

    let mut table = Table::new(vec![
        "model", "fast-only", "Sentinel", "IAL", "LRU", "slow-only",
    ]);
    let mut sentinel_vs_ial = Vec::new();
    for (i, model) in models.iter().enumerate() {
        let thr = |j: usize| -> f64 {
            outs[i * policies.len() + j]
                .as_ref()
                .expect("grid run")
                .throughput()
        };
        let fthr = thr(0);
        let s_norm = thr(1) / fthr;
        let ial_norm = thr(2) / fthr;
        sentinel_vs_ial.push(s_norm / ial_norm);
        table.row(vec![
            model.name(),
            "1.000".to_string(),
            format!("{s_norm:.3}"),
            format!("{ial_norm:.3}"),
            format!("{:.3}", thr(3) / fthr),
            format!("{:.3}", thr(4) / fthr),
        ]);
    }

    println!("normalized training throughput (fast = 20% of peak):\n");
    table.print();
    let avg: f64 = sentinel_vs_ial.iter().sum::<f64>() / sentinel_vs_ial.len() as f64;
    println!(
        "\nSentinel outperforms IAL by {:.1}% on average (paper: 18%)",
        (avg - 1.0) * 100.0
    );
}
