//! End-to-end driver: REAL training through all three layers, plus the
//! Sentinel coordinator managing the same workload's memory.
//!
//! 1. Loads the AOT artifacts (`make artifacts`: JAX/Pallas → HLO text),
//!    compiles them on the PJRT CPU client, and trains the MLP for a few
//!    hundred SGD steps on a synthetic teacher-labelled dataset, logging
//!    the loss curve — proving L1 (Pallas kernel) → L2 (JAX model) →
//!    L3 (Rust runtime) compose.
//! 2. Mirrors the trained model as a `ModelGraph` whose per-layer compute
//!    times are the *measured* PJRT wall times, then hands that graph to
//!    a `RunSpec` — the Sentinel coordinator driving placement for the
//!    exact workload that just ran for real.
//!
//! Run: `cargo run --release --features pjrt --example train_e2e -- [steps] [lr]`
//! (defaults: 300 steps, lr 0.05) — after vendoring the `xla`/`anyhow`
//! crates and declaring them in Cargo.toml; the offline manifest ships
//! with no dependencies, so the `pjrt` feature alone does not build.
//! Results recorded in EXPERIMENTS.md.

use sentinel_hm::api::{PolicyKind, RunSpec};
use sentinel_hm::coordinator::sentinel::SentinelConfig;
use sentinel_hm::dnn::graph::GraphBuilder;
use sentinel_hm::dnn::layer::LayerKind;
use sentinel_hm::dnn::ModelGraph;
use sentinel_hm::runtime::{trainer::synthetic_batch, Manifest, MlpTrainer, Runtime, StepTiming};
use sentinel_hm::util::table::fmt_bytes;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: u32 = args.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let lr: f32 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0.1);

    // ---- phase 1: real training through PJRT ------------------------
    let rt = match Runtime::load("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("cannot load artifacts ({e:#}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    let m = rt.manifest.clone();
    println!(
        "e2e: {}-layer MLP, {} parameters, batch {} on PJRT/{} — {} artifacts",
        m.layers,
        m.param_count(),
        m.batch,
        rt.platform(),
        rt.artifact_names().len(),
    );

    let mut trainer = MlpTrainer::new(&rt, 42).expect("trainer init");
    let mut timing_acc = StepTiming::default();
    let mut first_loss = f32::NAN;
    let mut last_loss = f32::NAN;
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let (x, y) = synthetic_batch(&m, step as u64 % 64).expect("batch");
        let (loss, t) = trainer.train_step(&x, &y, lr).expect("train step");
        if step == 0 {
            first_loss = loss;
        }
        last_loss = loss;
        timing_acc.fwd_ns += t.fwd_ns;
        timing_acc.loss_ns += t.loss_ns;
        timing_acc.bwd_ns += t.bwd_ns;
        timing_acc.opt_ns += t.opt_ns;
        if step % 20 == 0 || step + 1 == steps {
            println!("step {step:4}  loss {loss:.4}");
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "\n{} steps in {:.1}s = {:.2} steps/s | loss {first_loss:.4} → {last_loss:.4}",
        steps,
        wall,
        steps as f64 / wall
    );
    let bound = if steps >= 200 { 0.7 } else { 1.0 };
    assert!(
        last_loss < first_loss * bound,
        "training must reduce loss: {first_loss} → {last_loss}"
    );
    let per_layer_fwd_ns = timing_acc.fwd_ns as f64 / steps as f64 / (m.layers as f64);
    let per_layer_bwd_ns = timing_acc.bwd_ns as f64 / steps as f64 / (m.layers as f64);

    // ---- phase 2: Sentinel coordinates the same workload ------------
    println!("\n— Sentinel managing this workload on the paper's HM testbed —");
    let g = mlp_graph(&m, per_layer_fwd_ns, per_layer_bwd_ns);
    let peak = g.peak_live_bytes();
    let fast = (peak * 3 / 5).max(64 * 4096);
    println!(
        "mirrored graph: {} layers, {} objects, live peak {}, fast = {}",
        g.n_layers(),
        g.objects.len(),
        fmt_bytes(peak),
        fmt_bytes(fast),
    );
    // The MLP's layers run in microseconds; scale the interval-boundary
    // synchronization cost accordingly (a single-process runtime, not
    // the kernel move_pages path the zoo models assume).
    let cfg = SentinelConfig { boundary_overhead_ns: 5_000.0, ..Default::default() };
    let out = RunSpec::for_graph(g.clone())
        .policy(PolicyKind::Sentinel(cfg))
        .fast_bytes(fast)
        .steps(14)
        .run()
        .expect("sentinel run");
    let reference = RunSpec::for_graph(g)
        .policy(PolicyKind::FastOnly)
        .steps(6)
        .run()
        .expect("fast-only run");
    let cases = out.cases.expect("sentinel cases");
    let ratio = out.throughput() / reference.throughput();
    println!(
        "sentinel {:.1} steps/s vs fast-only {:.1} steps/s → {:.1}% | \
         {} pages migrated | cases 1/2/3 = {}/{}/{}",
        out.throughput(),
        reference.throughput(),
        ratio * 100.0,
        out.result.total_migrations(),
        cases.case1,
        cases.case2,
        cases.case3,
    );
}

/// Mirror the artifact MLP as a [`ModelGraph`]: weights + activations +
/// gradients with the real byte sizes, per-layer compute time taken from
/// the measured PJRT wall times (the machine runs at 1 "GFLOPS" so
/// `flops == ns`).
fn mlp_graph(m: &Manifest, fwd_ns: f64, bwd_ns: f64) -> ModelGraph {
    const F32: u64 = 4;
    let l = m.layers as u32;
    let mut b = GraphBuilder::new("mlp-e2e", m.batch as u32);
    let mut dims = vec![m.dim];
    dims.extend(std::iter::repeat(m.hidden).take(m.layers - 1));
    dims.push(m.classes);
    for i in 0..l {
        b.layer(LayerKind::Dense, format!("fwd/l{i}"), fwd_ns, false);
    }
    for i in (0..l).rev() {
        b.layer(LayerKind::Dense, format!("bwd/l{i}"), bwd_ns, true);
    }
    let last = 2 * l - 1;
    for i in 0..l {
        let bwd = 2 * l - 1 - i;
        let (fan_in, fan_out) = (dims[i as usize] as u64, dims[i as usize + 1] as u64);
        let w = b.persistent(fan_in * fan_out * F32);
        b.access(w, i, 2);
        b.access(w, bwd, 2);
        b.access(w, last, 1);
        let act = b.object(m.batch as u64 * fan_out * F32, i, bwd);
        b.access(act, i, 1);
        if i + 1 < l {
            b.access(act, i + 1, 1);
        }
        b.access(act, bwd, 1);
        let grad = b.object(fan_in * fan_out * F32, bwd, last);
        b.access(grad, bwd, 1);
        if bwd != last {
            b.access(grad, last, 1);
        }
        // The literal copies + scratch the runtime makes each layer.
        b.temp(i, m.batch as u64 * fan_out * F32 / 2, 2);
        b.temp(bwd, m.batch as u64 * fan_out * F32 / 2, 2);
    }
    b.finish()
}
