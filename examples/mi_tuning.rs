//! Migration-interval anatomy (Figs. 7 & 8): sweep MI for ResNet_v1-32
//! with 1 GB of fast memory, print the throughput curve, the sweet spot,
//! the per-step Case 1/2/3 counts, and the Eq. 1/2 constraint values the
//! solver prunes with.
//!
//! Run: `cargo run --release --example mi_tuning`

use sentinel_hm::api::shared_workload;
use sentinel_hm::coordinator::interval::{candidate_intervals, estimate};
use sentinel_hm::dnn::zoo::Model;
use sentinel_hm::figures::{fig7_mi_sweep, fig8_cases};
use sentinel_hm::sim::MachineSpec;
use sentinel_hm::util::table::{fmt_bytes, Table};

fn main() {
    let fast = 1u64 << 30; // the paper's Fig. 7 configuration
    let model = Model::ResNetV1 { depth: 32 };
    // Same seed as the figure suite, so the MI sweep below reuses the
    // cached graph instead of rebuilding it.
    let w = shared_workload(model, 0x5E17);
    let g = &w.graph;
    let spec = MachineSpec::paper_testbed(fast);

    println!("== Eq. 1/2 constraint values (S = {}) ==\n", fmt_bytes(fast));
    let mut t = Table::new(vec![
        "MI", "Data(MI)", "RS(MI)", "T(MI)", "space ok", "time ok",
    ]);
    for mi in 1..=16 {
        let e = estimate(g, mi, &spec, fast);
        t.row(vec![
            mi.to_string(),
            fmt_bytes(e.data_bytes),
            fmt_bytes(e.rs_bytes),
            format!("{:.1} ms", e.time_ns / 1e6),
            e.space_ok.to_string(),
            e.time_ok.to_string(),
        ]);
    }
    t.print();
    let candidates = candidate_intervals(g, &spec, fast, 5);
    println!("\nonline candidates (≤5, evenly sampled): {candidates:?}");

    let mis: Vec<u32> = (1..=16).collect();
    println!("\n== Fig 7 — throughput vs MI ==\n");
    let (rows, sp) = fig7_mi_sweep(fast, &mis);
    let max_thr = rows.iter().map(|r| r.1).fold(0.0, f64::max);
    for (mi, thr) in &rows {
        let bar = "#".repeat((thr / max_thr * 50.0) as usize);
        let mark = if *mi == sp { "  <- SP" } else { "" };
        println!("MI={mi:2} {thr:6.3} steps/s {bar}{mark}");
    }

    println!("\n== Fig 8 — migration cases per training step ==\n");
    let mut t = Table::new(vec!["MI", "Case 1", "Case 2", "Case 3"]);
    for (mi, c1, c2, c3) in fig8_cases(fast, &mis) {
        t.row(vec![mi.to_string(), c1.to_string(), c2.to_string(), c3.to_string()]);
    }
    t.print();
    println!(
        "\nexpected shape (paper §4.4): Case 3 grows as MI shrinks, \
         Case 2 grows as MI grows, sweet spot in between (SP={sp})."
    );
}
