#!/usr/bin/env bash
# Pre-PR verification: build, test, format check (when available), and a
# CLI smoke run exercising the batched compare path and the JSON writer.
# Documented in README.md — run before every PR.
set -euo pipefail
cd "$(dirname "$0")/.."

# Warnings are errors: the offline crate is std-only and warning-free,
# and CI (.github/workflows/ci.yml) runs this same script.
export RUSTFLAGS="${RUSTFLAGS:--Dwarnings}"

# Meta-check: every suite under rust/tests/ must have a [[test]] entry in
# Cargo.toml. The manifest sets autotests = false, so an unregistered
# suite is SILENTLY skipped by `cargo test` — it would rot green.
echo "== meta: every rust/tests/*.rs is registered in Cargo.toml =="
missing=0
for f in rust/tests/*.rs; do
  name="$(basename "$f" .rs)"
  if ! grep -q "^name = \"$name\"$" Cargo.toml; then
    echo "UNREGISTERED TEST SUITE: $f has no [[test]] entry in Cargo.toml" >&2
    missing=1
  fi
done
[ "$missing" -eq 0 ] || exit 1

echo "== cargo build --release (RUSTFLAGS=$RUSTFLAGS) =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# Docs must stay warning-free (broken intra-doc links, missing docs on
# the api surface, malformed HTML in doc comments all fail here) — the
# companion of ARCHITECTURE.md's documentation invariants.
export RUSTDOCFLAGS="${RUSTDOCFLAGS:--Dwarnings}"
echo "== cargo doc --no-deps (RUSTDOCFLAGS=$RUSTDOCFLAGS) =="
cargo doc --no-deps

if cargo fmt --version >/dev/null 2>&1; then
  echo "== cargo fmt --check =="
  cargo fmt --check || echo "warning: rustfmt differences (non-fatal)"
else
  echo "== cargo fmt not installed; skipping format check =="
fi

echo "== smoke: sentinel compare --steps 4 --json =="
out="$(./target/release/sentinel compare --steps 4 --json)"
if command -v python3 >/dev/null 2>&1; then
  printf '%s' "$out" | python3 -c 'import json,sys; json.load(sys.stdin)'
else
  case "$out" in
    "{"*"}") ;;
    *) echo "compare --json did not emit a JSON object" >&2; exit 1 ;;
  esac
fi

echo "== smoke: sentinel fleet --tenants 8 --machines 2 --json =="
out="$(./target/release/sentinel fleet --tenants 8 --machines 2 --json)"
if command -v python3 >/dev/null 2>&1; then
  printf '%s' "$out" | python3 -c 'import json,sys
o = json.load(sys.stdin)
assert o["jobs_offered"] == 8, o
assert o["completed"] + o["rejected"] == 8, o'
else
  case "$out" in
    "{"*"}") ;;
    *) echo "fleet --json did not emit a JSON object" >&2; exit 1 ;;
  esac
fi

echo "== smoke: sentinel faults --tenants 8 --fault-rate 0.05 --json =="
out="$(./target/release/sentinel faults --tenants 8 --fault-rate 0.05 --json)"
if command -v python3 >/dev/null 2>&1; then
  printf '%s' "$out" | python3 -c 'import json,sys
o = json.load(sys.stdin)
assert o["jobs_offered"] == 8, o
assert "faults" in o, "armed run must carry a degradation report"
assert o["faults"]["injected"] >= 0, o["faults"]'
else
  case "$out" in
    "{"*"}") ;;
    *) echo "faults --json did not emit a JSON object" >&2; exit 1 ;;
  esac
fi

echo "== smoke: sentinel slo --tenants 8 --fault-rate 0.05 --json =="
out="$(./target/release/sentinel slo --tenants 8 --fault-rate 0.05 --json)"
if command -v python3 >/dev/null 2>&1; then
  printf '%s' "$out" | python3 -c 'import json,sys
o = json.load(sys.stdin)
assert o["jobs_offered"] == 8, o
assert "faults" in o, "armed run must carry a degradation report"
s = o.get("slo")
assert s is not None, "armed watchdog must carry a mitigation ledger"
for k in ("violations", "boosts", "throttles", "evacuations", "drains"):
    assert s[k] >= 0, s
assert all("drained" in m for m in o["machines"]), o["machines"]'
else
  case "$out" in
    "{"*"}") ;;
    *) echo "slo --json did not emit a JSON object" >&2; exit 1 ;;
  esac
fi

echo "== smoke: sentinel dynamic resnet32 --kind var-batch --variability 0.25 --json =="
out="$(./target/release/sentinel dynamic resnet32 --kind var-batch --variability 0.25 --steps 12 --json)"
if command -v python3 >/dev/null 2>&1; then
  printf '%s' "$out" | python3 -c 'import json,sys
o = json.load(sys.stdin)
d = o.get("dynamics")
assert d is not None, "variability > 0 must carry a dynamics report"
assert d["detector"] is True, d
assert d["reprofiles"] == d["divergences"], d
assert d["stale_steps"] == 0, "armed detector must leave no stale exposure"'
else
  case "$out" in
    "{"*"}") ;;
    *) echo "dynamic --json did not emit a JSON object" >&2; exit 1 ;;
  esac
fi

echo "== smoke: checkpoint + resume reproduces the uninterrupted fleet run =="
# A checkpoint file is exactly what survives a mid-run kill: resuming
# from an intermediate file is the kill-at-that-boundary scenario. The
# interrupt (Ctrl-C) path is exercised deterministically by the
# checkpoint_interrupt suite; here we prove the end-to-end CLI story:
# write checkpoints, "lose" the process, resume, diff the JSON.
ckdir="$(mktemp -d)"
trap 'rm -rf "$ckdir"' EXIT
fleet_args="--tenants 8 --machines 2 --seed 17 --json"
base="$(./target/release/sentinel fleet $fleet_args)"
ckpt="$(./target/release/sentinel fleet $fleet_args --checkpoint-every 2 --checkpoint-dir "$ckdir")"
[ "$base" = "$ckpt" ] || { echo "checkpoint writing perturbed the fleet run" >&2; exit 1; }
first="$(ls "$ckdir"/fleet-*.ckpt | head -n 1)"
[ -n "$first" ] || { echo "no checkpoint files written to $ckdir" >&2; exit 1; }
resumed="$(./target/release/sentinel fleet $fleet_args --resume "$first")"
if [ "$base" = "$resumed" ]; then
  echo "resume from $(basename "$first") matches the uninterrupted run bit for bit"
else
  echo "resume from $first diverged from the uninterrupted run" >&2
  exit 1
fi

echo "verify: OK"
