#!/usr/bin/env bash
# Hot-path regression check: run the sim_hotpath and fleet_churn
# benches, merge their JSON summary lines, and diff the result against
# the committed baseline (BENCH_2.json by default; override with
# BENCH_BASELINE=<path>).
#
#   scripts/bench_check.sh            # compare a fresh run to the baseline
#   scripts/bench_check.sh --update   # re-measure and rewrite the baseline
#
# Checks applied in compare mode:
#   * absolute: engine_events_per_s must meet the ≥ 10 M events/s target
#     that rust/benches/sim_hotpath.rs prints;
#   * relative: rate fields must be ≥ RATIO× the baseline (default 0.5 —
#     generous, because baselines travel between machines; tighten with
#     BENCH_MIN_RATIO for same-machine CI).
# A baseline marked "provisional": true is a pre-measurement PLACEHOLDER,
# not a baseline: compare mode still runs the bench and applies the
# absolute events/s target (that signal must never go dark), but it
# refuses the relative diff and FAILS LOUDLY instead of informationally
# comparing against estimates — if you can run this script you have a
# working toolchain, so re-run with --update to write measured values
# (the written summary carries no provisional flag, which re-arms the
# relative comparison).
set -euo pipefail
cd "$(dirname "$0")/.."

BASELINE="${BENCH_BASELINE:-BENCH_2.json}"
MIN_RATIO="${BENCH_MIN_RATIO:-0.5}"
TARGET_EVENTS_PER_S="${BENCH_TARGET_EVENTS_PER_S:-10000000}"

PROVISIONAL=0
if [ "${1:-}" != "--update" ] && [ -f "$BASELINE" ] \
   && grep -q '"provisional"[[:space:]]*:[[:space:]]*true' "$BASELINE"; then
  PROVISIONAL=1
fi

echo "== cargo bench --bench sim_hotpath =="
out="$(cargo bench --bench sim_hotpath 2>&1)" || { printf '%s\n' "$out"; exit 1; }
printf '%s\n' "$out"
summary="$(printf '%s\n' "$out" | grep '^{' | tail -n 1)"
if [ -z "$summary" ]; then
  echo "bench_check: no JSON summary line in bench output" >&2
  exit 1
fi

# Fleet-scale churn bench: its summary fields (fleet_*_ns,
# fleet_tenants_per_s) ride in the same baseline object. Keep the
# headline scenario small here — this script exists for regression
# signal, not for the acceptance-scale 10k run.
echo "== cargo bench --bench fleet_churn (FLEET_BENCH_TENANTS=${FLEET_BENCH_TENANTS:-2000}) =="
fleet_out="$(FLEET_BENCH_TENANTS="${FLEET_BENCH_TENANTS:-2000}" cargo bench --bench fleet_churn 2>&1)" \
  || { printf '%s\n' "$fleet_out"; exit 1; }
printf '%s\n' "$fleet_out"
fleet_summary="$(printf '%s\n' "$fleet_out" | grep '^{' | tail -n 1)"
if [ -z "$fleet_summary" ]; then
  echo "bench_check: no JSON summary line in fleet_churn output" >&2
  exit 1
fi
if command -v python3 >/dev/null 2>&1; then
  summary="$(python3 -c '
import json, sys
a = json.loads(sys.argv[1])
b = json.loads(sys.argv[2])
a.update({k: v for k, v in b.items() if k != "bench"})
print(json.dumps(a))' "$summary" "$fleet_summary")"
else
  echo "bench_check: python3 not available; baseline keeps sim_hotpath fields only" >&2
fi

if [ "${1:-}" = "--update" ]; then
  printf '%s\n' "$summary" > "$BASELINE"
  echo "bench_check: baseline updated → $BASELINE"
  exit 0
fi

if [ ! -f "$BASELINE" ]; then
  echo "bench_check: no baseline at $BASELINE (run with --update to create one)" >&2
  exit 1
fi

if ! command -v python3 >/dev/null 2>&1; then
  echo "bench_check: python3 not available; skipping numeric comparison" >&2
  exit 0
fi

py_status=0
python3 - "$BASELINE" "$MIN_RATIO" "$TARGET_EVENTS_PER_S" "$PROVISIONAL" "$summary" <<'PY' \
  || py_status=$?
import json, sys

baseline_path, min_ratio, target = sys.argv[1], float(sys.argv[2]), float(sys.argv[3])
provisional = sys.argv[4] == "1"
fresh = json.loads(sys.argv[5])
with open(baseline_path) as f:
    base = json.load(f)
if provisional:
    # Placeholder baseline: the relative comparison would validate
    # nothing, so only the absolute target below applies (the shell
    # fails the run afterwards regardless).
    base = {}

failures, notes = [], []

ev = fresh.get("engine_events_per_s", 0.0)
if ev < target:
    failures.append(
        f"engine_events_per_s = {ev/1e6:.1f} M/s below the {target/1e6:.0f} M/s target"
    )

# Higher-is-better rates: fresh must hold MIN_RATIO of the baseline.
for key in (
    "engine_events_per_s",
    "engine_events_per_s_sealed_equiv",
    "sealed_speedup_vs_compiled",
    "lane_pages_per_s",
    "fleet_tenants_per_s",
):
    b, f_ = base.get(key), fresh.get(key)
    if not b or not f_:
        continue
    ratio = f_ / b
    line = f"{key}: fresh {f_:.3g} vs baseline {b:.3g} (ratio {ratio:.2f})"
    if ratio < min_ratio:
        failures.append(line)
    else:
        notes.append(line)

# Lower-is-better times: fresh must stay within 1/MIN_RATIO of baseline.
for key in (
    "engine_ns_per_step",
    "sentinel_e2e_ns_per_step",
    "alloc_access_free_ns_per_op",
    "fleet_200t_2m_serial_ns",
    "fleet_1k_8m_par_ns",
):
    b, f_ = base.get(key), fresh.get(key)
    if not b or not f_:
        continue
    ratio = f_ / b
    line = f"{key}: fresh {f_:.3g} vs baseline {b:.3g} (ratio {ratio:.2f})"
    if ratio > 1.0 / min_ratio:
        failures.append(line)
    else:
        notes.append(line)

for n in notes:
    print(f"bench_check: {n}")
if failures:
    for f_ in failures:
        print(f"bench_check: FAIL {f_}", file=sys.stderr)
    sys.exit(1)
print("bench_check: absolute target OK" if provisional else "bench_check: OK")
PY

if [ "$PROVISIONAL" = 1 ]; then
  echo "bench_check: FAIL — $BASELINE is still a provisional placeholder" >&2
  echo "bench_check: its numbers are pre-measurement estimates, so the relative" >&2
  echo "bench_check: comparison was skipped (the absolute target above still ran)." >&2
  echo "bench_check: run 'scripts/bench_check.sh --update' — you have a working" >&2
  echo "bench_check: toolchain if you just ran this — to write a measured baseline." >&2
  exit 1
fi
exit "$py_status"
